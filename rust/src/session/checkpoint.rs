//! Checkpoint/restart for both mesh roles: versioned binary snapshots
//! (DESIGN.md §8 for the label party, §9 for feature parties).
//!
//! A [`SessionSnapshot`] captures everything the label party needs to
//! restart a session that dialers can `Rejoin`: the logical-session
//! epoch, the next communication round, the session size, the codec
//! negotiated per link (so a resumed session keeps each peer's wire
//! format without re-running any handshake), and the label party's
//! trainable state (params + AdaGrad accumulators) as plain tensors.
//!
//! Snapshot layout (little-endian, `ckpt_round_<round>.celuckpt`):
//!   `"CELU"` `[u16 version=1]` `[u32 epoch]` `[u64 round]`
//!   `[u16 parties]` `[u16 n_links]` n_links × `[u16 peer][u8 codec][u32 param]`
//!   `[u32 n_params]` tensors… `[u32 n_accs]` tensors… `[u64 fnv1a]`
//! where each tensor is `[u8 dtype][u8 ndim][u32 dim…][payload]` (the
//! wire tensor layout) and the trailing word is the FNV-1a 64 hash of
//! every preceding byte — a truncated or bit-flipped snapshot fails
//! before any state is restored. Decoding applies the protocol layer's
//! hostile-header discipline: dimension products are overflow-checked
//! and every length is validated against the remaining buffer *before*
//! the payload allocation it implies.
//!
//! A [`FeatureSnapshot`] is the symmetric artifact for a feature party
//! (DESIGN.md §9): the same epoch/round/parties header plus the party's
//! own id, the codec negotiated on its label link, and the bottom
//! model's params + AdaGrad accumulators. The completed-round count
//! *is* the workset-cursor position — `BatchCursor` is a pure function
//! of the seed, so a restarted process fast-forwards `round` draws and
//! lands exactly where the crash left it, instead of replaying from
//! round 0.
//!
//! Feature snapshot layout (little-endian,
//! `ckpt_p<party>_round_<round>.celuckpt`):
//!   `"CELF"` `[u16 version=1]` `[u32 epoch]` `[u64 round]`
//!   `[u16 parties]` `[u16 party]` `[u8 codec][u32 param]`
//!   `[u32 n_params]` tensors… `[u32 n_accs]` tensors… `[u64 fnv1a]`
//! Both formats share the tensor codec, the FNV-1a trailer, the atomic
//! tmp-write + rename save path, and the hostile-header decode
//! discipline; the distinct magics mean neither loader can be fed the
//! other role's file by mistake.

use std::collections::BTreeSet;

use crate::compress::CodecKind;
use crate::metrics::facade::EventSink;
use crate::session::supervisor::SessionEvent;
use crate::session::{PartyId, MAX_PARTIES};
use crate::tensor::{Data, DType, Tensor};

/// Current snapshot format version.
pub const SNAPSHOT_VERSION: u16 = 1;

/// Current feature-snapshot format version (versioned separately so
/// either layout can evolve without disturbing the other's fixtures).
pub const FEATURE_SNAPSHOT_VERSION: u16 = 1;

/// How many times a checkpoint write is attempted before the caller
/// degrades to training without a fresh snapshot (DESIGN.md §9).
pub const SAVE_ATTEMPTS: u32 = 2;

/// File magic.
const MAGIC: &[u8; 4] = b"CELU";

/// Feature-snapshot file magic.
const FEATURE_MAGIC: &[u8; 4] = b"CELF";

/// Hard cap on a decoded tensor's element count (1 Gi elements = 4 GiB
/// payload): a corrupt header is refused by arithmetic, not by an
/// attempted allocation.
const MAX_TENSOR_ELEMS: usize = 1 << 30;

/// The codec negotiated on one activation lane at snapshot time.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct LinkCodecState {
    pub peer: PartyId,
    pub codec: CodecKind,
}

/// A restartable label-party snapshot.
#[derive(Debug, Clone, PartialEq)]
pub struct SessionSnapshot {
    /// Logical-session epoch (`supervisor::session_epoch`): a `Rejoin`
    /// into the restarted session must echo this.
    pub epoch: u32,
    /// The next communication round the resumed session runs.
    pub round: u64,
    /// Session size the snapshot was taken under.
    pub parties: u16,
    /// Per-link codec state, one entry per feature lane.
    pub links: Vec<LinkCodecState>,
    /// Label-party trainable parameters, in manifest order.
    pub params: Vec<Tensor>,
    /// AdaGrad accumulators, aligned with `params`.
    pub accs: Vec<Tensor>,
}

fn fnv1a(bytes: &[u8]) -> u64 {
    let mut h: u64 = 0xcbf2_9ce4_8422_2325;
    for &b in bytes {
        h ^= b as u64;
        h = h.wrapping_mul(0x0000_0100_0000_01b3);
    }
    h
}

fn encode_tensor(out: &mut Vec<u8>, t: &Tensor) {
    out.push(t.dtype().code());
    out.push(t.shape.len() as u8);
    for &d in &t.shape {
        out.extend_from_slice(&(d as u32).to_le_bytes());
    }
    match &t.data {
        Data::F32(v) => {
            for x in v.iter() {
                out.extend_from_slice(&x.to_le_bytes());
            }
        }
        Data::I32(v) => {
            for x in v.iter() {
                out.extend_from_slice(&x.to_le_bytes());
            }
        }
    }
}

struct Reader<'a> {
    buf: &'a [u8],
    pos: usize,
}

impl<'a> Reader<'a> {
    fn take(&mut self, n: usize) -> anyhow::Result<&'a [u8]> {
        let end = self
            .pos
            .checked_add(n)
            .ok_or_else(|| anyhow::anyhow!("snapshot offset overflow"))?;
        anyhow::ensure!(end <= self.buf.len(), "truncated snapshot");
        let s = &self.buf[self.pos..end];
        self.pos = end;
        Ok(s)
    }

    fn u8(&mut self) -> anyhow::Result<u8> {
        Ok(self.take(1)?[0])
    }

    fn u16(&mut self) -> anyhow::Result<u16> {
        Ok(u16::from_le_bytes(self.take(2)?.try_into().unwrap()))
    }

    fn u32(&mut self) -> anyhow::Result<u32> {
        Ok(u32::from_le_bytes(self.take(4)?.try_into().unwrap()))
    }

    fn u64(&mut self) -> anyhow::Result<u64> {
        Ok(u64::from_le_bytes(self.take(8)?.try_into().unwrap()))
    }
}

fn decode_tensor(r: &mut Reader) -> anyhow::Result<Tensor> {
    let dtype = DType::from_code(r.u8()?)?;
    let ndim = r.u8()? as usize;
    let mut shape = Vec::with_capacity(ndim);
    for _ in 0..ndim {
        shape.push(r.u32()? as usize);
    }
    // Overflow-checked element count, bounded BEFORE the payload read
    // sizes an allocation.
    let n: usize = shape
        .iter()
        .try_fold(1usize, |acc, &d| acc.checked_mul(d))
        .ok_or_else(|| anyhow::anyhow!("snapshot tensor shape overflow"))?;
    anyhow::ensure!(
        n <= MAX_TENSOR_ELEMS,
        "snapshot tensor of {n} elements exceeds the {MAX_TENSOR_ELEMS} \
         cap"
    );
    let payload = r.take(
        n.checked_mul(4)
            .ok_or_else(|| anyhow::anyhow!("snapshot tensor size overflow"))?,
    )?;
    Ok(match dtype {
        DType::F32 => Tensor::f32(
            shape,
            payload
                .chunks_exact(4)
                .map(|c| f32::from_le_bytes(c.try_into().unwrap()))
                .collect::<Vec<_>>(),
        ),
        DType::I32 => Tensor::i32(
            shape,
            payload
                .chunks_exact(4)
                .map(|c| i32::from_le_bytes(c.try_into().unwrap()))
                .collect::<Vec<_>>(),
        ),
    })
}

impl SessionSnapshot {
    /// Serialize to the versioned binary layout (checksum included).
    pub fn encode(&self) -> Vec<u8> {
        let mut out = Vec::new();
        out.extend_from_slice(MAGIC);
        out.extend_from_slice(&SNAPSHOT_VERSION.to_le_bytes());
        out.extend_from_slice(&self.epoch.to_le_bytes());
        out.extend_from_slice(&self.round.to_le_bytes());
        out.extend_from_slice(&self.parties.to_le_bytes());
        out.extend_from_slice(&(self.links.len() as u16).to_le_bytes());
        for l in &self.links {
            out.extend_from_slice(&l.peer.0.to_le_bytes());
            out.push(l.codec.code());
            out.extend_from_slice(&l.codec.param().to_le_bytes());
        }
        out.extend_from_slice(&(self.params.len() as u32).to_le_bytes());
        for t in &self.params {
            encode_tensor(&mut out, t);
        }
        out.extend_from_slice(&(self.accs.len() as u32).to_le_bytes());
        for t in &self.accs {
            encode_tensor(&mut out, t);
        }
        let h = fnv1a(&out);
        out.extend_from_slice(&h.to_le_bytes());
        out
    }

    /// Decode and validate a snapshot buffer.
    pub fn decode(buf: &[u8]) -> anyhow::Result<Self> {
        anyhow::ensure!(
            buf.len() >= MAGIC.len() + 2 + 8,
            "snapshot too short ({} bytes)", buf.len()
        );
        anyhow::ensure!(
            &buf[..4] == MAGIC,
            "not a CELU checkpoint (bad magic)"
        );
        // Checksum over everything except the trailing hash word.
        let body = &buf[..buf.len() - 8];
        let stored =
            u64::from_le_bytes(buf[buf.len() - 8..].try_into().unwrap());
        let computed = fnv1a(body);
        anyhow::ensure!(
            stored == computed,
            "snapshot checksum mismatch (stored {stored:#018x}, \
             computed {computed:#018x}) — truncated or corrupt file"
        );
        let mut r = Reader { buf: body, pos: MAGIC.len() };
        let version = r.u16()?;
        anyhow::ensure!(
            version == SNAPSHOT_VERSION,
            "unsupported snapshot version {version} (this build reads \
             {SNAPSHOT_VERSION})"
        );
        let epoch = r.u32()?;
        let round = r.u64()?;
        let parties = r.u16()?;
        anyhow::ensure!(
            (2..=MAX_PARTIES).contains(&parties),
            "snapshot declares a {parties}-party session \
             (valid: 2..={MAX_PARTIES})"
        );
        let n_links = r.u16()? as usize;
        anyhow::ensure!(
            n_links == parties as usize - 1,
            "snapshot carries {n_links} link states for a \
             {parties}-party session"
        );
        let mut links = Vec::with_capacity(n_links);
        let mut seen = BTreeSet::new();
        for _ in 0..n_links {
            let peer = r.u16()?;
            anyhow::ensure!(
                peer >= 1 && peer < parties,
                "snapshot link peer {peer} out of range \
                 (valid feature ids: 1..={})", parties - 1
            );
            anyhow::ensure!(
                seen.insert(peer),
                "snapshot has duplicate link state for P{peer}"
            );
            let code = r.u8()?;
            let param = r.u32()?;
            links.push(LinkCodecState {
                peer: PartyId(peer),
                codec: CodecKind::from_wire(code, param)?,
            });
        }
        let n_params = r.u32()? as usize;
        let mut params = Vec::with_capacity(n_params.min(1 << 16));
        for _ in 0..n_params {
            params.push(decode_tensor(&mut r)?);
        }
        let n_accs = r.u32()? as usize;
        anyhow::ensure!(
            n_accs == n_params,
            "snapshot has {n_accs} accumulators for {n_params} params"
        );
        let mut accs = Vec::with_capacity(n_accs.min(1 << 16));
        for _ in 0..n_accs {
            accs.push(decode_tensor(&mut r)?);
        }
        anyhow::ensure!(
            r.pos == body.len(),
            "trailing bytes in snapshot ({} of {})", r.pos, body.len()
        );
        Ok(SessionSnapshot { epoch, round, parties, links, params, accs })
    }

    /// Write the snapshot under `dir` as `ckpt_round_<round>.celuckpt`
    /// (via a temp file + rename, so a crash mid-write never leaves a
    /// half snapshot under the final name). Returns the path written.
    pub fn save(&self, dir: &str) -> anyhow::Result<String> {
        std::fs::create_dir_all(dir)
            .map_err(|e| anyhow::anyhow!("creating {dir}: {e}"))?;
        let name = format!("ckpt_round_{:08}.celuckpt", self.round);
        let path = std::path::Path::new(dir).join(&name);
        let tmp = std::path::Path::new(dir).join(format!("{name}.tmp"));
        std::fs::write(&tmp, self.encode())
            .map_err(|e| anyhow::anyhow!("writing {}: {e}", tmp.display()))?;
        std::fs::rename(&tmp, &path)
            .map_err(|e| anyhow::anyhow!("renaming {}: {e}", tmp.display()))?;
        Ok(path.to_string_lossy().into_owned())
    }

    /// Load and validate a snapshot file.
    pub fn load(path: &str) -> anyhow::Result<Self> {
        let buf = std::fs::read(path)
            .map_err(|e| anyhow::anyhow!("reading checkpoint {path}: {e}"))?;
        Self::decode(&buf).map_err(|e| {
            anyhow::anyhow!("decoding checkpoint {path}: {e:#}")
        })
    }
}

/// A restartable feature-party snapshot (DESIGN.md §9).
#[derive(Debug, Clone, PartialEq)]
pub struct FeatureSnapshot {
    /// Logical-session epoch (`supervisor::session_epoch`): the
    /// `Rejoin` this snapshot authorizes must echo it.
    pub epoch: u32,
    /// Communication rounds completed before the snapshot — also the
    /// deterministic workset-cursor position the restarted process
    /// fast-forwards to, and the `last_round` its `Rejoin` carries.
    pub round: u64,
    /// Session size the snapshot was taken under.
    pub parties: u16,
    /// The feature party this snapshot belongs to (`1..parties`).
    pub party: u16,
    /// Codec negotiated on the label link at snapshot time, pinned on
    /// resume so the wire format survives the restart.
    pub codec: CodecKind,
    /// Bottom-model trainable parameters, in manifest order.
    pub params: Vec<Tensor>,
    /// AdaGrad accumulators, aligned with `params`.
    pub accs: Vec<Tensor>,
}

impl FeatureSnapshot {
    /// Serialize to the versioned binary layout (checksum included).
    pub fn encode(&self) -> Vec<u8> {
        let mut out = Vec::new();
        out.extend_from_slice(FEATURE_MAGIC);
        out.extend_from_slice(&FEATURE_SNAPSHOT_VERSION.to_le_bytes());
        out.extend_from_slice(&self.epoch.to_le_bytes());
        out.extend_from_slice(&self.round.to_le_bytes());
        out.extend_from_slice(&self.parties.to_le_bytes());
        out.extend_from_slice(&self.party.to_le_bytes());
        out.push(self.codec.code());
        out.extend_from_slice(&self.codec.param().to_le_bytes());
        out.extend_from_slice(&(self.params.len() as u32).to_le_bytes());
        for t in &self.params {
            encode_tensor(&mut out, t);
        }
        out.extend_from_slice(&(self.accs.len() as u32).to_le_bytes());
        for t in &self.accs {
            encode_tensor(&mut out, t);
        }
        let h = fnv1a(&out);
        out.extend_from_slice(&h.to_le_bytes());
        out
    }

    /// Decode and validate a feature snapshot buffer.
    pub fn decode(buf: &[u8]) -> anyhow::Result<Self> {
        anyhow::ensure!(
            buf.len() >= FEATURE_MAGIC.len() + 2 + 8,
            "feature snapshot too short ({} bytes)", buf.len()
        );
        anyhow::ensure!(
            &buf[..4] == FEATURE_MAGIC,
            "not a CELF feature checkpoint (bad magic)"
        );
        // Checksum over everything except the trailing hash word.
        let body = &buf[..buf.len() - 8];
        let stored =
            u64::from_le_bytes(buf[buf.len() - 8..].try_into().unwrap());
        let computed = fnv1a(body);
        anyhow::ensure!(
            stored == computed,
            "feature snapshot checksum mismatch (stored {stored:#018x}, \
             computed {computed:#018x}) — truncated or corrupt file"
        );
        let mut r = Reader { buf: body, pos: FEATURE_MAGIC.len() };
        let version = r.u16()?;
        anyhow::ensure!(
            version == FEATURE_SNAPSHOT_VERSION,
            "unsupported feature snapshot version {version} (this build \
             reads {FEATURE_SNAPSHOT_VERSION})"
        );
        let epoch = r.u32()?;
        let round = r.u64()?;
        let parties = r.u16()?;
        anyhow::ensure!(
            (2..=MAX_PARTIES).contains(&parties),
            "feature snapshot declares a {parties}-party session \
             (valid: 2..={MAX_PARTIES})"
        );
        let party = r.u16()?;
        anyhow::ensure!(
            party >= 1 && party < parties,
            "feature snapshot belongs to party {party} in a \
             {parties}-party session (valid feature ids: 1..={})",
            parties - 1
        );
        let code = r.u8()?;
        let param = r.u32()?;
        let codec = CodecKind::from_wire(code, param)?;
        let n_params = r.u32()? as usize;
        let mut params = Vec::with_capacity(n_params.min(1 << 16));
        for _ in 0..n_params {
            params.push(decode_tensor(&mut r)?);
        }
        let n_accs = r.u32()? as usize;
        anyhow::ensure!(
            n_accs == n_params,
            "feature snapshot has {n_accs} accumulators for {n_params} \
             params"
        );
        let mut accs = Vec::with_capacity(n_accs.min(1 << 16));
        for _ in 0..n_accs {
            accs.push(decode_tensor(&mut r)?);
        }
        anyhow::ensure!(
            r.pos == body.len(),
            "trailing bytes in feature snapshot ({} of {})", r.pos,
            body.len()
        );
        Ok(FeatureSnapshot {
            epoch, round, parties, party, codec, params, accs,
        })
    }

    /// Write the snapshot under `dir` as
    /// `ckpt_p<party>_round_<round>.celuckpt` (temp file + rename, so a
    /// crash mid-write never leaves a half snapshot under the final
    /// name). Returns the path written.
    pub fn save(&self, dir: &str) -> anyhow::Result<String> {
        std::fs::create_dir_all(dir)
            .map_err(|e| anyhow::anyhow!("creating {dir}: {e}"))?;
        let name = format!("ckpt_p{:03}_round_{:08}.celuckpt",
                           self.party, self.round);
        let path = std::path::Path::new(dir).join(&name);
        let tmp = std::path::Path::new(dir).join(format!("{name}.tmp"));
        std::fs::write(&tmp, self.encode())
            .map_err(|e| anyhow::anyhow!("writing {}: {e}", tmp.display()))?;
        std::fs::rename(&tmp, &path)
            .map_err(|e| anyhow::anyhow!("renaming {}: {e}", tmp.display()))?;
        Ok(path.to_string_lossy().into_owned())
    }

    /// Load and validate a feature snapshot file.
    pub fn load(path: &str) -> anyhow::Result<Self> {
        let buf = std::fs::read(path)
            .map_err(|e| anyhow::anyhow!("reading checkpoint {path}: {e}"))?;
        Self::decode(&buf).map_err(|e| {
            anyhow::anyhow!("decoding checkpoint {path}: {e:#}")
        })
    }
}

/// Run a checkpoint write with bounded retry (DESIGN.md §9): a failing
/// attempt — disk full, permission, dead mount — is retried up to
/// [`SAVE_ATTEMPTS`] times total before the error is handed back, so a
/// transient hiccup costs nothing and a persistent one degrades the
/// session to training-without-snapshots instead of aborting the round.
///
/// The outcome is emitted through `sink` as the session's own fault
/// history: `CheckpointWritten{round, path}` on success,
/// `CheckpointFailed{round, error}` once the retries are exhausted —
/// callers no longer hand-build the events.
pub fn save_with_retry<F>(round: u64, sink: &dyn EventSink, mut attempt: F)
                          -> anyhow::Result<String>
where
    F: FnMut() -> anyhow::Result<String>,
{
    let mut last: Option<anyhow::Error> = None;
    for try_no in 1..=SAVE_ATTEMPTS {
        match attempt() {
            Ok(path) => {
                sink.emit(&SessionEvent::CheckpointWritten {
                    round,
                    path: path.clone(),
                });
                return Ok(path);
            }
            Err(e) => {
                if try_no < SAVE_ATTEMPTS {
                    log::warn!(
                        "checkpoint write attempt {try_no}/{SAVE_ATTEMPTS} \
                         failed: {e:#} — retrying"
                    );
                }
                last = Some(e);
            }
        }
    }
    let err = last.expect("SAVE_ATTEMPTS >= 1");
    sink.emit(&SessionEvent::CheckpointFailed {
        round,
        error: format!("{err:#}"),
    });
    Err(err)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sample() -> SessionSnapshot {
        SessionSnapshot {
            epoch: 0x0102_0304,
            round: 5,
            parties: 3,
            links: vec![
                LinkCodecState { peer: PartyId(1), codec: CodecKind::Fp16 },
                LinkCodecState {
                    peer: PartyId(2),
                    codec: CodecKind::Identity,
                },
            ],
            params: vec![Tensor::f32(vec![2], vec![1.0, -2.0])],
            accs: vec![Tensor::f32(vec![2], vec![0.5, 0.25])],
        }
    }

    fn hex_to_bytes(hex: &str) -> Vec<u8> {
        let compact: String =
            hex.chars().filter(|c| !c.is_whitespace()).collect();
        assert_eq!(compact.len() % 2, 0, "odd hex length");
        (0..compact.len())
            .step_by(2)
            .map(|i| u8::from_str_radix(&compact[i..i + 2], 16).unwrap())
            .collect()
    }

    #[test]
    fn golden_snapshot_encode_is_byte_identical() {
        // Captured at introduction time; machine-checked against an
        // independent Python rebuild of the layout (incl. the FNV-1a
        // trailer). Byte drift in the snapshot format fails here.
        let hex = "43454c55 0100 04030201 0500000000000000 0300 0200 \
                   0100 01 00000000 0200 00 00000000 \
                   01000000 00 01 02000000 0000803f 000000c0 \
                   01000000 00 01 02000000 0000003f 0000803e \
                   07f8a2e7b3c083b2";
        let enc = sample().encode();
        assert_eq!(enc, hex_to_bytes(hex), "snapshot layout drifted: {}",
                   enc.iter().map(|b| format!("{b:02x}"))
                       .collect::<String>());
    }

    #[test]
    fn golden_snapshot_decode_recovers_the_snapshot() {
        let s = sample();
        assert_eq!(SessionSnapshot::decode(&s.encode()).unwrap(), s);
    }

    #[test]
    fn roundtrip_with_i32_and_topk() {
        let s = SessionSnapshot {
            epoch: 9,
            round: u64::MAX,
            parties: 2,
            links: vec![LinkCodecState {
                peer: PartyId(1),
                codec: CodecKind::TopK(48),
            }],
            params: vec![
                Tensor::f32(vec![2, 3], vec![0.0; 6]),
                Tensor::i32(vec![1], vec![-7]),
            ],
            accs: vec![
                Tensor::f32(vec![2, 3], vec![0.1; 6]),
                Tensor::i32(vec![1], vec![3]),
            ],
        };
        assert_eq!(SessionSnapshot::decode(&s.encode()).unwrap(), s);
    }

    #[test]
    fn truncations_and_corruption_error_cleanly() {
        let enc = sample().encode();
        for cut in 0..enc.len() {
            assert!(SessionSnapshot::decode(&enc[..cut]).is_err(),
                    "truncation at {cut} decoded");
        }
        // Any single bit flip trips the checksum (or a validation).
        for at in 0..enc.len() {
            let mut bent = enc.clone();
            bent[at] ^= 1;
            assert!(SessionSnapshot::decode(&bent).is_err(),
                    "bit flip at {at} decoded");
        }
        let mut trailing = enc;
        trailing.push(0);
        assert!(SessionSnapshot::decode(&trailing).is_err());
    }

    #[test]
    fn hostile_headers_are_refused_by_arithmetic() {
        // A snapshot declaring a huge tensor must die on the element
        // cap / length checks, not on an attempted allocation. Build a
        // valid prefix then a hostile tensor header with a fresh
        // checksum so only the size check can refuse it.
        let mut body = Vec::new();
        body.extend_from_slice(MAGIC);
        body.extend_from_slice(&SNAPSHOT_VERSION.to_le_bytes());
        body.extend_from_slice(&7u32.to_le_bytes()); // epoch
        body.extend_from_slice(&1u64.to_le_bytes()); // round
        body.extend_from_slice(&2u16.to_le_bytes()); // parties
        body.extend_from_slice(&1u16.to_le_bytes()); // n_links
        body.extend_from_slice(&1u16.to_le_bytes()); // peer
        body.push(0); // identity
        body.extend_from_slice(&0u32.to_le_bytes()); // param
        body.extend_from_slice(&1u32.to_le_bytes()); // n_params
        body.push(0); // f32
        body.push(4); // ndim
        for _ in 0..4 {
            body.extend_from_slice(&u32::MAX.to_le_bytes());
        }
        let h = fnv1a(&body);
        body.extend_from_slice(&h.to_le_bytes());
        let e = SessionSnapshot::decode(&body).unwrap_err().to_string();
        assert!(e.contains("overflow") || e.contains("cap"),
                "hostile tensor header not refused arithmetically: {e}");
    }

    #[test]
    fn decode_validates_session_shape() {
        // Mismatched link count.
        let mut s = sample();
        s.links.pop();
        let enc = s.encode();
        assert!(SessionSnapshot::decode(&enc).is_err());
        // Duplicate peer.
        let mut s = sample();
        s.links[1].peer = PartyId(1);
        assert!(SessionSnapshot::decode(&s.encode()).is_err());
        // Out-of-range peer.
        let mut s = sample();
        s.links[1].peer = PartyId(9);
        assert!(SessionSnapshot::decode(&s.encode()).is_err());
        // Accs/params mismatch.
        let mut s = sample();
        s.accs.pop();
        assert!(SessionSnapshot::decode(&s.encode()).is_err());
    }

    #[test]
    fn save_and_load_roundtrip() {
        let dir = std::env::temp_dir().join(format!(
            "celu_ckpt_test_{}", std::process::id()
        ));
        let dir = dir.to_string_lossy().into_owned();
        let s = sample();
        let path = s.save(&dir).unwrap();
        assert!(path.contains("ckpt_round_00000005.celuckpt"));
        assert_eq!(SessionSnapshot::load(&path).unwrap(), s);
        // Unknown version is refused loudly.
        let mut enc = s.encode();
        enc[4] = 9;
        let body_len = enc.len() - 8;
        let h = fnv1a(&enc[..body_len]);
        enc[body_len..].copy_from_slice(&h.to_le_bytes());
        let e = SessionSnapshot::decode(&enc).unwrap_err().to_string();
        assert!(e.contains("version"), "{e}");
        std::fs::remove_dir_all(&dir).ok();
    }
}

#[cfg(test)]
mod feature_tests {
    //! The feature-snapshot suite mirrors the label suite above: golden
    //! bytes, every-byte truncation/corruption, hostile tensor headers
    //! refused by arithmetic, shape validation, save/load, and the
    //! cross-magic confusion checks unique to having two roles.

    use super::*;

    fn fsample() -> FeatureSnapshot {
        FeatureSnapshot {
            epoch: 0x0102_0304,
            round: 5,
            parties: 3,
            party: 2,
            codec: CodecKind::Fp16,
            params: vec![Tensor::f32(vec![2], vec![1.0, -2.0])],
            accs: vec![Tensor::f32(vec![2], vec![0.5, 0.25])],
        }
    }

    fn hex_to_bytes(hex: &str) -> Vec<u8> {
        let compact: String =
            hex.chars().filter(|c| !c.is_whitespace()).collect();
        assert_eq!(compact.len() % 2, 0, "odd hex length");
        (0..compact.len())
            .step_by(2)
            .map(|i| u8::from_str_radix(&compact[i..i + 2], 16).unwrap())
            .collect()
    }

    #[test]
    fn golden_feature_snapshot_encode_is_byte_identical() {
        // Captured at introduction time; machine-checked against an
        // independent Python rebuild of the layout (incl. the FNV-1a
        // trailer). Byte drift in the feature snapshot format fails
        // here.
        let hex = "43454c46 0100 04030201 0500000000000000 0300 0200 \
                   01 00000000 \
                   01000000 00 01 02000000 0000803f 000000c0 \
                   01000000 00 01 02000000 0000003f 0000803e \
                   bfd5c58cd1368b77";
        let enc = fsample().encode();
        assert_eq!(enc, hex_to_bytes(hex),
                   "feature snapshot layout drifted: {}",
                   enc.iter().map(|b| format!("{b:02x}"))
                       .collect::<String>());
    }

    #[test]
    fn golden_feature_snapshot_decode_recovers_the_snapshot() {
        let s = fsample();
        assert_eq!(FeatureSnapshot::decode(&s.encode()).unwrap(), s);
    }

    #[test]
    fn feature_roundtrip_with_i32_and_topk() {
        let s = FeatureSnapshot {
            epoch: 9,
            round: u64::MAX,
            parties: 2,
            party: 1,
            codec: CodecKind::TopK(48),
            params: vec![
                Tensor::f32(vec![2, 3], vec![0.0; 6]),
                Tensor::i32(vec![1], vec![-7]),
            ],
            accs: vec![
                Tensor::f32(vec![2, 3], vec![0.1; 6]),
                Tensor::i32(vec![1], vec![3]),
            ],
        };
        assert_eq!(FeatureSnapshot::decode(&s.encode()).unwrap(), s);
    }

    #[test]
    fn feature_truncations_and_corruption_error_cleanly() {
        let enc = fsample().encode();
        // Truncation at every byte boundary.
        for cut in 0..enc.len() {
            assert!(FeatureSnapshot::decode(&enc[..cut]).is_err(),
                    "truncation at {cut} decoded");
        }
        // Any single bit flip trips the checksum (or a validation) —
        // this covers wrong magic, wrong epoch, and wrong round bytes.
        for at in 0..enc.len() {
            let mut bent = enc.clone();
            bent[at] ^= 1;
            assert!(FeatureSnapshot::decode(&bent).is_err(),
                    "bit flip at {at} decoded");
        }
        // A corrupted FNV trailer specifically (flip a high trailer
        // bit, leaving the body intact).
        let mut bad_hash = enc.clone();
        let last = bad_hash.len() - 1;
        bad_hash[last] ^= 0x80;
        let e = FeatureSnapshot::decode(&bad_hash).unwrap_err()
            .to_string();
        assert!(e.contains("checksum"), "trailer corruption not named: {e}");
        let mut trailing = enc;
        trailing.push(0);
        assert!(FeatureSnapshot::decode(&trailing).is_err());
    }

    #[test]
    fn feature_hostile_headers_are_refused_by_arithmetic() {
        // A snapshot declaring a huge tensor must die on the element
        // cap / length checks, not on an attempted allocation.
        let mut body = Vec::new();
        body.extend_from_slice(FEATURE_MAGIC);
        body.extend_from_slice(&FEATURE_SNAPSHOT_VERSION.to_le_bytes());
        body.extend_from_slice(&7u32.to_le_bytes()); // epoch
        body.extend_from_slice(&1u64.to_le_bytes()); // round
        body.extend_from_slice(&2u16.to_le_bytes()); // parties
        body.extend_from_slice(&1u16.to_le_bytes()); // party
        body.push(0); // identity
        body.extend_from_slice(&0u32.to_le_bytes()); // param
        body.extend_from_slice(&1u32.to_le_bytes()); // n_params
        body.push(0); // f32
        body.push(4); // ndim
        for _ in 0..4 {
            body.extend_from_slice(&u32::MAX.to_le_bytes());
        }
        let h = fnv1a(&body);
        body.extend_from_slice(&h.to_le_bytes());
        let e = FeatureSnapshot::decode(&body).unwrap_err().to_string();
        assert!(e.contains("overflow") || e.contains("cap"),
                "hostile tensor header not refused arithmetically: {e}");
    }

    #[test]
    fn feature_decode_validates_session_shape() {
        // Party 0 (the label) can never own a feature snapshot.
        let mut s = fsample();
        s.party = 0;
        assert!(FeatureSnapshot::decode(&s.encode()).is_err());
        // Party id must sit inside the declared session.
        let mut s = fsample();
        s.party = 3;
        assert!(FeatureSnapshot::decode(&s.encode()).is_err());
        // Session size is bounded.
        let mut s = fsample();
        s.parties = 1;
        assert!(FeatureSnapshot::decode(&s.encode()).is_err());
        let mut s = fsample();
        s.parties = MAX_PARTIES + 1;
        s.party = 5;
        assert!(FeatureSnapshot::decode(&s.encode()).is_err());
        // Accs/params mismatch.
        let mut s = fsample();
        s.accs.pop();
        assert!(FeatureSnapshot::decode(&s.encode()).is_err());
    }

    #[test]
    fn feature_save_and_load_roundtrip() {
        let dir = std::env::temp_dir().join(format!(
            "celu_fckpt_test_{}", std::process::id()
        ));
        let dir = dir.to_string_lossy().into_owned();
        let s = fsample();
        let path = s.save(&dir).unwrap();
        assert!(path.contains("ckpt_p002_round_00000005.celuckpt"));
        assert_eq!(FeatureSnapshot::load(&path).unwrap(), s);
        // Unknown version is refused loudly (re-hash so only the
        // version check can refuse it).
        let mut enc = s.encode();
        enc[4] = 9;
        let body_len = enc.len() - 8;
        let h = fnv1a(&enc[..body_len]);
        enc[body_len..].copy_from_slice(&h.to_le_bytes());
        let e = FeatureSnapshot::decode(&enc).unwrap_err().to_string();
        assert!(e.contains("version"), "{e}");
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn the_two_magics_are_mutually_exclusive() {
        // A label loader fed a feature snapshot (or vice versa) must
        // refuse on the magic — before any checksum or field parsing.
        let feature = fsample().encode();
        let e = SessionSnapshot::decode(&feature).unwrap_err().to_string();
        assert!(e.contains("magic"), "label loader ate a CELF file: {e}");
        let label = SessionSnapshot {
            epoch: 1,
            round: 2,
            parties: 2,
            links: vec![LinkCodecState {
                peer: PartyId(1),
                codec: CodecKind::Identity,
            }],
            params: vec![],
            accs: vec![],
        }
        .encode();
        let e = FeatureSnapshot::decode(&label).unwrap_err().to_string();
        assert!(e.contains("magic"), "feature loader ate a CELU file: {e}");
    }

    #[test]
    fn save_with_retry_succeeds_after_a_transient_failure() {
        let (tx, rx) = std::sync::mpsc::channel();
        let sink = crate::metrics::facade::ChannelSink::new(tx);
        let mut calls = 0;
        let path = save_with_retry(7, &sink, || {
            calls += 1;
            if calls == 1 {
                anyhow::bail!("disk hiccup");
            }
            Ok("ok.celuckpt".to_string())
        })
        .unwrap();
        assert_eq!(path, "ok.celuckpt");
        assert_eq!(calls, 2);
        // One success event, nothing else: the transient failure never
        // reaches the session's fault history.
        assert_eq!(rx.try_recv().unwrap(),
                   SessionEvent::CheckpointWritten {
                       round: 7,
                       path: "ok.celuckpt".into(),
                   });
        assert!(rx.try_recv().is_err());
    }

    #[test]
    fn save_with_retry_gives_up_after_bounded_attempts() {
        let (tx, rx) = std::sync::mpsc::channel();
        let sink = crate::metrics::facade::ChannelSink::new(tx);
        let mut calls = 0;
        let err = save_with_retry(9, &sink, || {
            calls += 1;
            anyhow::bail!("disk full");
        })
        .unwrap_err();
        assert_eq!(calls, SAVE_ATTEMPTS, "retry not bounded");
        assert!(err.to_string().contains("disk full"));
        match rx.try_recv().unwrap() {
            SessionEvent::CheckpointFailed { round: 9, error } => {
                assert!(error.contains("disk full"), "{error}");
            }
            other => panic!("expected CheckpointFailed, got {other:?}"),
        }
    }
}
