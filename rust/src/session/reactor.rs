//! The service plane's readiness loop: nonblocking first-contact
//! classification over every socket a [`super::server::SessionServer`]
//! has accepted but not yet routed (DESIGN.md §11).
//!
//! The single-session listener ([`super::bootstrap::SessionListener`])
//! affords a thread pool: it admits at most K−1 peers, once, so a
//! bounded number of blocking `read`s is a fine substrate. A
//! multi-session server cannot spend a thread per connection — dozens
//! of meshes joining concurrently (plus scrapers, plus probes) would
//! turn the admit pool into the bottleneck the pool was built to
//! avoid. The reactor replaces those blocking reads with one
//! single-threaded poll loop over incremental per-connection state
//! machines:
//!
//! ```text
//!       accept            4 bytes read               body complete
//! socket ───► Head ──┬──► Body{len} ────────────────► Frame(Message)
//!                    │   (len ≤ MAX_BOOTSTRAP_FRAME)
//!                    └──► Http ──────────────────────► Http(HttpRequest)
//!                        (head == "GET ")  "\r\n\r\n"
//! ```
//!
//! Every state carries the same [`JOIN_READ_TIMEOUT`] deadline the
//! blocking path enforces: a connection that never completes its first
//! contact is dropped at expiry, having cost the reactor nothing but
//! its buffer — a byte-trickler cannot wedge a slot because there are
//! no slots. Classification is exactly the PR-7 dispatch, applied
//! incrementally: a little-endian length word ≤
//! [`MAX_BOOTSTRAP_FRAME`] opens a bootstrap frame, the ASCII `GET `
//! (read as a length word: ~540 MB) opens an observability request.
//!
//! The reactor is deliberately `std`-only — no `epoll`/`kqueue`
//! binding exists in-tree, and the contact population is small (joins
//! are rare events; admitted sockets leave the reactor for their
//! session's transport immediately), so an `O(contacts)` scan per tick
//! at [`ACCEPT_POLL`] cadence is the right cost/complexity point.

use std::io::Read;
use std::net::{SocketAddr, TcpListener, TcpStream};
use std::time::Instant;

use crate::protocol::{decode_frame, Message};

use super::bootstrap::{parse_http_request, HttpRequest,
                       JOIN_READ_TIMEOUT, MAX_BOOTSTRAP_FRAME,
                       MAX_HTTP_REQUEST};

/// One accepted connection's incremental first-contact read.
enum ContactState {
    /// Reading the opening 4 bytes (length word or `GET `).
    Head { buf: [u8; 4], got: usize },
    /// Reading a bootstrap frame body of known length.
    Body { buf: Vec<u8>, got: usize },
    /// Accumulating an HTTP header block up to `\r\n\r\n`.
    Http { buf: Vec<u8> },
}

/// A socket parked in the reactor until its first contact resolves.
pub(crate) struct Contact {
    stream: TcpStream,
    addr: SocketAddr,
    state: ContactState,
    deadline: Instant,
}

/// A resolved first contact, ready for the server to route. The stream
/// is handed back in *blocking* mode (the reactor put it in
/// nonblocking mode to read it): acks are tiny synchronous writes, and
/// an admitted socket becomes a transport, which owns its own modes.
pub(crate) enum Ready {
    /// A decoded headerless bootstrap frame (Join/Rejoin path).
    Frame(Message, TcpStream),
    /// A parsed observability request.
    Http(HttpRequest, TcpStream),
}

enum Step {
    /// Still mid-read; keep the contact parked.
    Pending,
    Resolved(Ready),
    /// EOF, junk, or expiry: drop the connection, log `why`.
    Dead(String),
}

impl Contact {
    fn new(stream: TcpStream, addr: SocketAddr) -> anyhow::Result<Self> {
        stream.set_nonblocking(true)?;
        Ok(Contact {
            stream,
            addr,
            state: ContactState::Head { buf: [0; 4], got: 0 },
            deadline: Instant::now() + JOIN_READ_TIMEOUT,
        })
    }

    /// Drain whatever bytes are available and advance the state
    /// machine. Never blocks.
    fn poll(&mut self) -> Step {
        if Instant::now() >= self.deadline {
            return Step::Dead(format!(
                "first contact from {} incomplete after {:?}",
                self.addr, JOIN_READ_TIMEOUT
            ));
        }
        loop {
            match &mut self.state {
                ContactState::Head { buf, got } => {
                    let n = match read_some(&mut self.stream,
                                            &mut buf[*got..]) {
                        ReadSome::Bytes(n) => n,
                        ReadSome::WouldBlock => return Step::Pending,
                        ReadSome::Closed(why) => return Step::Dead(why),
                    };
                    *got += n;
                    if *got < 4 {
                        continue;
                    }
                    if buf == b"GET " {
                        self.state = ContactState::Http {
                            buf: Vec::with_capacity(128),
                        };
                        continue;
                    }
                    let len = u32::from_le_bytes(*buf) as usize;
                    if len == 0 || len > MAX_BOOTSTRAP_FRAME {
                        return Step::Dead(format!(
                            "bootstrap frame of {len} bytes from {} \
                             (max {MAX_BOOTSTRAP_FRAME}) — not a \
                             session peer", self.addr
                        ));
                    }
                    self.state = ContactState::Body {
                        buf: vec![0; len],
                        got: 0,
                    };
                }
                ContactState::Body { buf, got } => {
                    let n = match read_some(&mut self.stream,
                                            &mut buf[*got..]) {
                        ReadSome::Bytes(n) => n,
                        ReadSome::WouldBlock => return Step::Pending,
                        ReadSome::Closed(why) => return Step::Dead(why),
                    };
                    *got += n;
                    if *got < buf.len() {
                        continue;
                    }
                    return match decode_headerless(buf) {
                        Ok(msg) => match self.unpark() {
                            Ok(stream) => {
                                Step::Resolved(Ready::Frame(msg, stream))
                            }
                            Err(e) => Step::Dead(e),
                        },
                        Err(e) => Step::Dead(format!(
                            "undecodable bootstrap frame from {}: {e:#}",
                            self.addr
                        )),
                    };
                }
                ContactState::Http { buf } => {
                    let mut byte = [0u8; 1];
                    let n = match read_some(&mut self.stream, &mut byte) {
                        ReadSome::Bytes(n) => n,
                        ReadSome::WouldBlock => return Step::Pending,
                        ReadSome::Closed(why) => return Step::Dead(why),
                    };
                    debug_assert_eq!(n, 1);
                    buf.push(byte[0]);
                    if buf.len() > MAX_HTTP_REQUEST {
                        return Step::Dead(format!(
                            "HTTP request from {} exceeds \
                             {MAX_HTTP_REQUEST} bytes — not a scraper",
                            self.addr
                        ));
                    }
                    if !buf.ends_with(b"\r\n\r\n") {
                        continue;
                    }
                    return match parse_http_request(buf) {
                        Ok(req) => match self.unpark() {
                            Ok(stream) => {
                                Step::Resolved(Ready::Http(req, stream))
                            }
                            Err(e) => Step::Dead(e),
                        },
                        Err(e) => Step::Dead(format!(
                            "malformed HTTP request from {}: {e:#}",
                            self.addr
                        )),
                    };
                }
            }
        }
    }

    /// Restore blocking mode before handing the stream onward.
    fn unpark(&mut self) -> Result<TcpStream, String> {
        self.stream
            .set_nonblocking(false)
            .and_then(|()| self.stream.try_clone())
            .map_err(|e| format!(
                "unparking {} from the reactor: {e}", self.addr))
    }
}

enum ReadSome {
    Bytes(usize),
    WouldBlock,
    Closed(String),
}

fn read_some(stream: &mut TcpStream, buf: &mut [u8]) -> ReadSome {
    match stream.read(buf) {
        Ok(0) => ReadSome::Closed("peer closed mid-contact".into()),
        Ok(n) => ReadSome::Bytes(n),
        Err(e) if e.kind() == std::io::ErrorKind::WouldBlock => {
            ReadSome::WouldBlock
        }
        Err(e) if e.kind() == std::io::ErrorKind::Interrupted => {
            ReadSome::Bytes(0)
        }
        Err(e) => ReadSome::Closed(format!("read error: {e}")),
    }
}

/// Decode a complete bootstrap frame body, enforcing the headerless
/// rule (the reactor's analogue of `recv_bootstrap_body`, minus the
/// socket reads).
fn decode_headerless(buf: &[u8]) -> anyhow::Result<Message> {
    let (header, msg) = decode_frame(buf)?;
    anyhow::ensure!(
        header.is_none(),
        "bootstrap frames are headerless — link identity is \
         established by Join itself, not the v2 envelope"
    );
    Ok(msg)
}

/// The poll loop: one nonblocking listener plus every in-flight first
/// contact. [`Reactor::poll`] is the only entry point — the server
/// calls it each tick and routes whatever resolved.
pub(crate) struct Reactor {
    listener: TcpListener,
    contacts: Vec<Contact>,
}

impl Reactor {
    pub(crate) fn new(listener: TcpListener) -> anyhow::Result<Self> {
        listener.set_nonblocking(true)?;
        Ok(Reactor { listener, contacts: Vec::new() })
    }

    pub(crate) fn local_addr(&self) -> anyhow::Result<SocketAddr> {
        Ok(self.listener.local_addr()?)
    }

    /// Connections currently mid-first-contact (telemetry and tests).
    pub(crate) fn in_flight(&self) -> usize {
        self.contacts.len()
    }

    /// One tick: accept whatever is queued, advance every contact,
    /// return the resolved ones. Dead contacts are dropped with a log
    /// line; nothing here blocks, so the caller owns the cadence
    /// (sleep [`super::bootstrap::ACCEPT_POLL`] between empty ticks).
    pub(crate) fn poll(&mut self) -> Vec<Ready> {
        loop {
            match self.listener.accept() {
                Ok((stream, addr)) => match Contact::new(stream, addr) {
                    Ok(c) => self.contacts.push(c),
                    Err(e) => log::warn!(
                        "reactor: registering {addr} failed: {e:#}"),
                },
                Err(e) if e.kind() == std::io::ErrorKind::WouldBlock => {
                    break;
                }
                Err(e) => {
                    log::warn!("reactor accept: {e}");
                    break;
                }
            }
        }
        let mut ready = Vec::new();
        let mut keep = Vec::with_capacity(self.contacts.len());
        for mut c in self.contacts.drain(..) {
            match c.poll() {
                Step::Pending => keep.push(c),
                Step::Resolved(r) => ready.push(r),
                Step::Dead(why) => log::warn!("reactor: {why}"),
            }
        }
        self.contacts = keep;
        ready
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::io::Write;
    use std::time::Duration;

    use crate::compress;
    use crate::session::bootstrap::send_bootstrap_frame;
    use crate::session::PartyId;

    fn reactor() -> (Reactor, String) {
        let l = TcpListener::bind("127.0.0.1:0").unwrap();
        let addr = l.local_addr().unwrap().to_string();
        (Reactor::new(l).unwrap(), addr)
    }

    fn poll_until(r: &mut Reactor, deadline: Duration) -> Vec<Ready> {
        let end = Instant::now() + deadline;
        while Instant::now() < end {
            let ready = r.poll();
            if !ready.is_empty() {
                return ready;
            }
            std::thread::sleep(Duration::from_millis(2));
        }
        Vec::new()
    }

    #[test]
    fn resolves_frames_and_http_without_blocking() {
        let (mut r, addr) = reactor();
        let mut join = TcpStream::connect(&addr).unwrap();
        send_bootstrap_frame(&mut join, &Message::Join {
            party: PartyId(1),
            parties: 3,
            codecs: compress::supported_mask(),
        }).unwrap();
        let mut http = TcpStream::connect(&addr).unwrap();
        http.write_all(
            b"GET /metrics HTTP/1.0\r\nAuthorization: Bearer tok\r\n\r\n")
            .unwrap();
        let mut got_frame = false;
        let mut got_http = false;
        let end = Instant::now() + Duration::from_secs(5);
        while (!got_frame || !got_http) && Instant::now() < end {
            for ready in r.poll() {
                match ready {
                    Ready::Frame(Message::Join { party, parties, .. },
                                 _s) => {
                        assert_eq!((party, parties), (PartyId(1), 3));
                        got_frame = true;
                    }
                    Ready::Frame(m, _) => panic!("unexpected frame {m:?}"),
                    Ready::Http(req, _s) => {
                        assert_eq!(req.path, "/metrics");
                        assert_eq!(req.auth.as_deref(),
                                   Some("Bearer tok"));
                        got_http = true;
                    }
                }
            }
            std::thread::sleep(Duration::from_millis(2));
        }
        assert!(got_frame && got_http, "contacts did not resolve");
        assert_eq!(r.in_flight(), 0);
    }

    #[test]
    fn byte_trickler_never_stalls_other_contacts() {
        let (mut r, addr) = reactor();
        // A trickler that sends one length byte and goes mute…
        let mut slow = TcpStream::connect(&addr).unwrap();
        slow.write_all(&[18u8]).unwrap();
        // …must not delay a complete Join arriving after it.
        let mut join = TcpStream::connect(&addr).unwrap();
        send_bootstrap_frame(&mut join, &Message::Join {
            party: PartyId(2),
            parties: 3,
            codecs: 0,
        }).unwrap();
        let ready = poll_until(&mut r, Duration::from_secs(5));
        assert_eq!(ready.len(), 1);
        assert!(matches!(&ready[0],
                         Ready::Frame(Message::Join { party, .. }, _)
                         if *party == PartyId(2)));
        // The trickler is still parked, on its own deadline.
        assert_eq!(r.in_flight(), 1);
    }

    #[test]
    fn junk_oversize_and_disconnects_are_dropped() {
        let (mut r, addr) = reactor();
        // Oversize length word (not `GET `, > MAX_BOOTSTRAP_FRAME).
        let mut junk = TcpStream::connect(&addr).unwrap();
        junk.write_all(&1000u32.to_le_bytes()).unwrap();
        // Mid-contact disconnect: 2 bytes then gone.
        let mut gone = TcpStream::connect(&addr).unwrap();
        gone.write_all(&[9, 0]).unwrap();
        drop(gone);
        // Oversized HTTP header block.
        let mut big = TcpStream::connect(&addr).unwrap();
        big.write_all(b"GET /metrics HTTP/1.0\r\n").unwrap();
        big.write_all(&vec![b'x'; 2 * MAX_HTTP_REQUEST]).unwrap();
        // Hostiles may die on the very tick that accepts them, so there
        // is no reliable in-flight transition to watch — the contract
        // is that none of them ever *resolves*, and none lingers past
        // its deadline.
        let settle = Instant::now() + Duration::from_millis(300);
        while Instant::now() < settle {
            assert!(r.poll().is_empty(), "a hostile contact resolved");
            std::thread::sleep(Duration::from_millis(2));
        }
        // The reactor still serves a well-formed peer afterwards.
        let mut join = TcpStream::connect(&addr).unwrap();
        send_bootstrap_frame(&mut join, &Message::Join {
            party: PartyId(1),
            parties: 2,
            codecs: 0,
        }).unwrap();
        let ready = poll_until(&mut r, Duration::from_secs(5));
        assert_eq!(ready.len(), 1);
        // Whatever hostiles were still parked (a trickler that never
        // finished) expire on their JOIN_READ_TIMEOUT deadline.
        let end = Instant::now() + JOIN_READ_TIMEOUT + Duration::from_secs(3);
        while r.in_flight() != 0 {
            assert!(Instant::now() < end,
                    "{} hostile contacts never expired", r.in_flight());
            let _ = r.poll();
            std::thread::sleep(Duration::from_millis(10));
        }
    }
}
