//! Supervised session lifecycle (DESIGN.md §8).
//!
//! The earlier session layer ran to completion or died: the first
//! transport error anywhere in the mesh tore the whole run down, which
//! throws away exactly the asset CELU-VFL exists to exploit — a workset
//! of cached statistics that keeps training productive between WAN
//! exchanges (paper §3.1). This module turns the run-to-completion
//! drivers into a supervised lifecycle:
//!
//! - [`SessionState`] — the five-state machine every supervised party
//!   walks: `Joining → Running → Degraded → Recovering → Done`.
//!   Transitions are validated; an illegal edge is a bug, not a log
//!   line.
//! - [`SessionEvent`] — typed lifecycle events (`PeerLost`,
//!   `PeerRejoined`, `StragglerTimeout`, `CheckpointWritten`) surfaced
//!   to the caller (and into `RunRecord`) instead of hard errors.
//! - [`LaneSet`] — the label party's supervised view of its activation
//!   lanes. Each round it collects one [`LaneInput`] per lane:
//!   `Fresh` statistics when the peer delivered in time, `Stale` (the
//!   lane's most recent cached activation — CELU-VFL's own local-update
//!   machinery reused as the degraded-mode path; instance weighting
//!   already discounts the extra staleness) after a bounded straggler
//!   wait (`--straggler-wait-ms`), and `Missing` only for a lane that
//!   never contributed anything. Dead lanes are re-admitted through the
//!   [`Readmission`](super::bootstrap::Readmission) point: a `Rejoin`
//!   dial is validated (epoch, id, round sanity), acked with the resume
//!   round, and the current round's derivative is replayed from a
//!   bounded per-lane resend buffer.
//!
//! Supervision is strictly opt-in: with no straggler budget and no
//! re-admission point the `LaneSet` reproduces the historic blocking
//! behaviour — byte-identical wire, identical error propagation — so
//! the two-party golden fixtures and the unsupervised trainer are
//! untouched.

use std::collections::VecDeque;
use std::sync::Arc;
use std::time::{Duration, Instant};

use crate::compress::{self, CodecKind};
use crate::config::RunConfig;
use crate::metrics::facade::{EventSink, Registry};
use crate::protocol::{outbound_stats, Lane, Message};
use crate::tensor::Tensor;
use crate::transport::tcp::TcpTransport;
use crate::transport::{LinkStats, Transport};
use crate::util::rng::Pcg;

use super::bootstrap::{send_bootstrap_frame, Readmission};
use super::checkpoint::LinkCodecState;
use super::{Link, PartyId, LABEL_PARTY};

/// How many recent derivative frames each lane buffers for rejoin
/// replay. Under the lock-step protocol a returning party needs at most
/// its one in-flight round, but the buffer is indexed by round, so a
/// longer outage simply finds the slot evicted (replay count 0) rather
/// than replaying the wrong frame.
pub const RESEND_DEPTH: usize = 32;

/// Poll cadence of the bounded straggler wait. Short enough that a
/// just-late frame costs sub-millisecond latency, long enough that a
/// full `--straggler-wait-ms` window doesn't burn a core.
const STRAGGLER_POLL: Duration = Duration::from_micros(500);

/// Pace of a degraded round when no straggler budget is configured but
/// a re-admission point is open: without it, a session whose lane died
/// would free-run every remaining round on stale statistics in
/// milliseconds, leaving a returning dialer no window to land in.
const DEGRADED_PACE: Duration = Duration::from_millis(500);

/// The logical-session epoch for a run seeded with `seed`. Derived, not
/// exchanged: every party of a session shares the config seed (the
/// paper's post-PSI alignment already requires it), so each derives the
/// same epoch independently and `Rejoin` can prove membership without
/// widening the bootstrap frames. A dialer from a different logical
/// session (different seed) is refused at the re-admission point.
pub fn session_epoch(seed: u64) -> u32 {
    Pcg::new(seed, 0xE90C).next_u32()
}

// ---- state machine ---------------------------------------------------------

/// Lifecycle state of a supervised session.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum SessionState {
    /// Mesh assembling (bootstrap / handshake).
    Joining,
    /// Every lane live and in lock-step.
    Running,
    /// At least one lane is behind or lost; rounds proceed on cached
    /// stale statistics.
    Degraded,
    /// A lost lane has been re-admitted and is converging back into
    /// lock-step.
    Recovering,
    /// The run ended (success or orderly failure).
    Done,
}

impl SessionState {
    pub fn label(self) -> &'static str {
        match self {
            SessionState::Joining => "joining",
            SessionState::Running => "running",
            SessionState::Degraded => "degraded",
            SessionState::Recovering => "recovering",
            SessionState::Done => "done",
        }
    }

    /// Legal edges of the lifecycle graph. Self-edges are allowed (and
    /// are no-ops at the supervisor level).
    fn can_transition(self, to: SessionState) -> bool {
        use SessionState::*;
        if self == to {
            return true;
        }
        matches!(
            (self, to),
            (Joining, Running)
                | (Joining, Done)
                | (Running, Degraded)
                | (Running, Done)
                | (Degraded, Recovering)
                | (Degraded, Running)
                | (Degraded, Done)
                | (Recovering, Running)
                | (Recovering, Degraded)
                | (Recovering, Done)
        )
    }
}

/// Typed lifecycle events. These replace hard errors for conditions the
/// session can survive; the label party records them into `RunRecord`
/// so a run's fault history is part of its artifact.
#[derive(Debug, Clone, PartialEq)]
pub enum SessionEvent {
    /// A lane's transport died mid-session.
    PeerLost { party: PartyId, round: u64 },
    /// A lost lane was re-admitted through `Rejoin`.
    PeerRejoined { party: PartyId, round: u64 },
    /// A lane missed the bounded straggler window; the round proceeded
    /// on its cached stale statistics.
    StragglerTimeout { party: PartyId, round: u64 },
    /// A restartable snapshot was written.
    CheckpointWritten { round: u64, path: String },
    /// A snapshot write failed (after bounded retry) and the session
    /// kept training without it — degraded durability, not an abort.
    CheckpointFailed { round: u64, error: String },
}

impl SessionEvent {
    pub fn kind(&self) -> &'static str {
        match self {
            SessionEvent::PeerLost { .. } => "peer_lost",
            SessionEvent::PeerRejoined { .. } => "peer_rejoined",
            SessionEvent::StragglerTimeout { .. } => "straggler_timeout",
            SessionEvent::CheckpointWritten { .. } => "checkpoint_written",
            SessionEvent::CheckpointFailed { .. } => "checkpoint_failed",
        }
    }

    pub fn party(&self) -> Option<PartyId> {
        match self {
            SessionEvent::PeerLost { party, .. }
            | SessionEvent::PeerRejoined { party, .. }
            | SessionEvent::StragglerTimeout { party, .. } => Some(*party),
            SessionEvent::CheckpointWritten { .. }
            | SessionEvent::CheckpointFailed { .. } => None,
        }
    }

    pub fn round(&self) -> u64 {
        match self {
            SessionEvent::PeerLost { round, .. }
            | SessionEvent::PeerRejoined { round, .. }
            | SessionEvent::StragglerTimeout { round, .. }
            | SessionEvent::CheckpointWritten { round, .. }
            | SessionEvent::CheckpointFailed { round, .. } => *round,
        }
    }
}

/// The session state machine plus its event plumbing. Events no longer
/// live in a supervisor-private `Vec`: every [`Self::record`] emits
/// through the metrics registry's [`EventSink`] (bounded log + per-kind
/// counters) plus any extra sinks a caller subscribed — the historic
/// `events()` / `take_events()` accessors read the registry's log, so
/// existing callers see the same data through the same API.
pub struct Supervisor {
    state: SessionState,
    epoch: u32,
    registry: Arc<Registry>,
    extra_sinks: Vec<Arc<dyn EventSink>>,
}

impl Supervisor {
    /// A supervisor over its own private registry (the historic
    /// behaviour; nothing else observes the events).
    pub fn new(epoch: u32) -> Self {
        Supervisor::with_registry(epoch, Registry::new())
    }

    /// A supervisor emitting into a shared session registry — the
    /// observability plane's path: the same registry feeds the scrape
    /// endpoint, the push stream, and the terminal `RunRecord`
    /// observer.
    pub fn with_registry(epoch: u32, registry: Arc<Registry>) -> Self {
        Supervisor {
            state: SessionState::Joining,
            epoch,
            registry,
            extra_sinks: Vec::new(),
        }
    }

    pub fn state(&self) -> SessionState {
        self.state
    }

    pub fn epoch(&self) -> u32 {
        self.epoch
    }

    /// The registry this supervisor emits into.
    pub fn registry(&self) -> &Arc<Registry> {
        &self.registry
    }

    /// Subscribe an additional sink; every recorded event fans out to
    /// it after the registry.
    pub fn add_sink(&mut self, sink: Arc<dyn EventSink>) {
        self.extra_sinks.push(sink);
    }

    pub fn events(&self) -> Vec<SessionEvent> {
        self.registry.events()
    }

    pub fn dropped_events(&self) -> u64 {
        self.registry.dropped_events()
    }

    pub fn take_events(&mut self) -> Vec<SessionEvent> {
        self.registry.take_events()
    }

    /// Record a lifecycle event: the registry sink logs it (bounded by
    /// [`crate::metrics::facade::EVENTS_CAP`]) and counts it per kind;
    /// extra sinks see it afterwards.
    pub fn record(&mut self, event: SessionEvent) {
        self.registry.emit(&event);
        for s in &self.extra_sinks {
            s.emit(&event);
        }
    }

    /// Move to `to`, validating the edge. A self-transition is a no-op.
    pub fn transition(&mut self, to: SessionState) -> anyhow::Result<()> {
        anyhow::ensure!(
            self.state.can_transition(to),
            "illegal session transition {} → {}",
            self.state.label(),
            to.label()
        );
        if self.state != to {
            log::debug!("session state {} → {}", self.state.label(),
                        to.label());
            self.state = to;
        }
        Ok(())
    }
}

// ---- supervised lanes ------------------------------------------------------

/// What one lane contributed to a round.
#[derive(Debug, Clone)]
pub enum LaneInput {
    /// This round's real activation arrived in time.
    Fresh(Tensor),
    /// The lane is behind or lost: its most recent cached activation
    /// stands in (the degraded-mode path; staleness weighting applies).
    Stale(Tensor),
    /// The lane never delivered any statistics yet.
    Missing,
}

impl LaneInput {
    pub fn tensor(&self) -> Option<&Tensor> {
        match self {
            LaneInput::Fresh(t) | LaneInput::Stale(t) => Some(t),
            LaneInput::Missing => None,
        }
    }

    pub fn is_fresh(&self) -> bool {
        matches!(self, LaneInput::Fresh(_))
    }
}

/// One supervised activation lane.
struct SupLane {
    peer: PartyId,
    transport: Arc<dyn Transport>,
    peer_codecs: Option<u32>,
    codec: CodecKind,
    /// Pre-handshake first frame, replayed into the first collect.
    stash: Option<Message>,
    alive: bool,
    /// Communication rounds whose activation this side consumed (the
    /// lane is "current" for round `r` once `completed == r + 1`).
    completed: u64,
    /// Most recent real activation from this peer (degraded stand-in).
    last_za: Option<Tensor>,
    /// This round's fresh activation, once received.
    fresh: Option<Tensor>,
    /// Recent outbound derivative frames, by round (rejoin replay).
    resend: VecDeque<(u64, Message)>,
    rejoins: u64,
}

/// The label party's supervised lane fan: owns per-lane liveness, the
/// bounded straggler wait, catch-up draining, the resend buffer, and
/// the re-admission of `Rejoin` dialers. See the module docs for the
/// opt-in semantics.
pub struct LaneSet {
    lanes: Vec<SupLane>,
    sup: Supervisor,
    parties: u16,
    v2: bool,
    wan: crate::config::WanProfile,
    straggler: Option<Duration>,
    readmission: Option<Readmission>,
    /// Supervision flag: lose-on-error + degraded stepping. False means
    /// the historic behaviour: the first transport error propagates.
    supervised: bool,
    /// Frames staged by [`Self::stage_derivatives`], awaiting
    /// [`Self::send_staged`]. One per lane.
    staged: Vec<Message>,
    catch_ups: u64,
    evals_discarded: u64,
    discards: u64,
}

impl LaneSet {
    /// Build the lane fan for the label party of `cfg`'s session.
    /// `readmission` is the TCP listener's re-admission point (`None`
    /// in-proc or when reconnects are not wanted).
    pub fn new(cfg: &RunConfig, links: &[Link],
               readmission: Option<Readmission>) -> Self {
        let straggler = if cfg.straggler_wait_ms > 0 {
            Some(Duration::from_millis(cfg.straggler_wait_ms))
        } else {
            None
        };
        let supervised = straggler.is_some() || readmission.is_some();
        let lanes = links
            .iter()
            .map(|l| SupLane {
                peer: l.peer,
                transport: l.transport.clone(),
                peer_codecs: l.peer_codecs,
                codec: CodecKind::Identity,
                stash: None,
                alive: true,
                completed: 0,
                last_za: None,
                fresh: None,
                resend: VecDeque::new(),
                rejoins: 0,
            })
            .collect();
        LaneSet {
            lanes,
            sup: Supervisor::new(session_epoch(cfg.seed)),
            parties: cfg.parties as u16,
            v2: cfg.parties > 2,
            wan: cfg.wan,
            straggler,
            readmission,
            supervised,
            staged: Vec::new(),
            catch_ups: 0,
            evals_discarded: 0,
            discards: 0,
        }
    }

    pub fn len(&self) -> usize {
        self.lanes.len()
    }

    pub fn is_empty(&self) -> bool {
        self.lanes.is_empty()
    }

    pub fn state(&self) -> SessionState {
        self.sup.state()
    }

    pub fn epoch(&self) -> u32 {
        self.sup.epoch()
    }

    /// Emit lifecycle events into (and publish link accounting to) the
    /// shared session registry instead of a private one. Binds every
    /// lane's transport handles as `LABEL → peer` rows, so the scrape
    /// and push exporters see the same cells the transports bump.
    pub fn with_registry(mut self, registry: Arc<Registry>) -> Self {
        for lane in &self.lanes {
            if let Some(h) = lane.transport.metrics() {
                registry.bind_link(LABEL_PARTY, lane.peer, &h);
            }
            // Pre-register the liveness family so a scrape taken before
            // the first collect already shows every lane (all live).
            Self::set_lane_gauges(&registry, lane.peer, 1.0, 0.0, 0.0);
        }
        self.sup = Supervisor::with_registry(self.sup.epoch(), registry);
        self
    }

    /// Publish one lane's liveness as three 0/1 gauges
    /// (`celu_lane_live`, `celu_lane_straggling`, `celu_lane_dead`,
    /// each labelled `peer="<id>"`): exactly one is 1 at any time, so a
    /// multi-session scrape shows at a glance which mesh is degraded
    /// and on which link.
    fn set_lane_gauges(registry: &Registry, peer: PartyId, live: f64,
                       straggling: f64, dead: f64) {
        let p = peer.0;
        registry.gauge(&format!("celu_lane_live{{peer=\"{p}\"}}"))
            .set(live);
        registry.gauge(&format!("celu_lane_straggling{{peer=\"{p}\"}}"))
            .set(straggling);
        registry.gauge(&format!("celu_lane_dead{{peer=\"{p}\"}}"))
            .set(dead);
    }

    /// The registry this lane set emits into (private unless
    /// [`Self::with_registry`] installed a shared one).
    pub fn registry(&self) -> &Arc<Registry> {
        self.sup.registry()
    }

    /// Subscribe an additional event sink (see [`Supervisor::add_sink`]).
    pub fn add_sink(&mut self, sink: Arc<dyn EventSink>) {
        self.sup.add_sink(sink);
    }

    pub fn supervisor_mut(&mut self) -> &mut Supervisor {
        &mut self.sup
    }

    pub fn take_events(&mut self) -> Vec<SessionEvent> {
        self.sup.take_events()
    }

    pub fn total_rejoins(&self) -> u64 {
        self.lanes.iter().map(|l| l.rejoins).sum()
    }

    pub fn catch_ups(&self) -> u64 {
        self.catch_ups
    }

    /// Eval-lane frames discarded from behind lanes (telemetry).
    pub fn evals_discarded(&self) -> u64 {
        self.evals_discarded
    }

    /// Garbled frames discarded in supervised mode (telemetry): frames
    /// that failed to decode or violated the protocol — e.g. a chaos
    /// campaign's corrupt-frame injection — and were dropped instead of
    /// tearing the session down. See [`Self::consume_or_discard`].
    pub fn discards(&self) -> u64 {
        self.discards
    }

    /// The codec negotiated on each lane (checkpoint state).
    pub fn codec_states(&self) -> Vec<LinkCodecState> {
        self.lanes
            .iter()
            .map(|l| LinkCodecState { peer: l.peer, codec: l.codec })
            .collect()
    }

    /// Per-lane sender-side accounting. Replaced transports are folded
    /// in at swap time ([`crate::metrics::facade::LinkHandles::charge`]
    /// in `process_rejoins`), so the live transport's totals are the
    /// lane's full history.
    pub fn link_stats(&self) -> Vec<(PartyId, LinkStats)> {
        self.lanes
            .iter()
            .map(|l| (l.peer, l.transport.stats()))
            .collect()
    }

    /// Lane indices that are live and in lock-step at `round` (their
    /// activation for `round` was consumed) — the eval participants.
    pub fn current_lanes(&self, round: u64) -> Vec<usize> {
        self.lanes
            .iter()
            .enumerate()
            .filter(|(_, l)| l.alive && l.completed == round + 1)
            .map(|(i, _)| i)
            .collect()
    }

    pub fn peer(&self, i: usize) -> PartyId {
        self.lanes[i].peer
    }

    /// Negotiate each lane's wire codec. Join-time masks
    /// (`Link::peer_codecs`) pre-negotiate without any wire exchange;
    /// lanes without a mask run the historic in-band `Hello` handshake
    /// (pre-handshake peers fall back to identity, byte-identical).
    /// `pinned` (checkpoint resume) overrides negotiation entirely with
    /// the snapshot's per-link codec state.
    pub fn handshake(&mut self, cfg: &RunConfig,
                     pinned: Option<&[LinkCodecState]>)
                     -> anyhow::Result<()> {
        for i in 0..self.lanes.len() {
            let peer = self.lanes[i].peer;
            let requested = cfg.codec_for(peer.0);
            if let Some(states) = pinned {
                let st = states.iter().find(|s| s.peer == peer)
                    .ok_or_else(|| anyhow::anyhow!(
                        "checkpoint carries no codec state for {peer} — \
                         the session topology changed since the snapshot"
                    ))?;
                self.lanes[i].codec = st.codec;
                continue;
            }
            if let Some(mask) = self.lanes[i].peer_codecs {
                let eff = compress::negotiate(requested, Some(mask));
                if eff != requested {
                    log::warn!(
                        "[{peer}] peer cannot decode codec {} (join-time \
                         mask {mask:#x}) — sending uncompressed",
                        requested.label()
                    );
                }
                self.lanes[i].codec = eff;
                continue;
            }
            let first = self.lanes[i].transport.recv()?;
            match first {
                Message::Hello { codecs: peer_mask } => {
                    self.lanes[i].transport.send(Message::Hello {
                        codecs: compress::supported_mask(),
                    })?;
                    let eff = compress::negotiate(requested,
                                                  Some(peer_mask));
                    if eff != requested {
                        log::warn!(
                            "[{peer}] peer cannot decode codec {} \
                             (mask {peer_mask:#x}) — sending uncompressed",
                            requested.label()
                        );
                    }
                    self.lanes[i].codec = eff;
                }
                first => {
                    if requested != CodecKind::Identity {
                        log::warn!(
                            "[{peer}] compress = {} requested but peer \
                             opened without a handshake — sending \
                             uncompressed",
                            requested.label()
                        );
                    }
                    self.lanes[i].stash = Some(first);
                    self.lanes[i].codec = CodecKind::Identity;
                }
            }
        }
        self.sup.transition(SessionState::Running)
    }

    /// Collect one [`LaneInput`] per lane for `round`. Supervised mode
    /// waits at most the straggler budget and substitutes cached stale
    /// statistics; unsupervised mode blocks exactly like the historic
    /// label loop and propagates the first error. Errors are still
    /// returned for protocol violations (skew, unexpected frames) in
    /// both modes, and when *no* lane has ever contributed.
    pub fn collect(&mut self, round: u64)
                   -> anyhow::Result<Vec<LaneInput>> {
        self.sup.registry().set_round(round);
        self.process_rejoins(round)?;
        for i in 0..self.lanes.len() {
            self.drain_lane(i, round)?;
        }
        match self.straggler {
            Some(wait) => self.wait_deadline(round, wait)?,
            None => self.wait_blocking(round)?,
        }
        let mut out = Vec::with_capacity(self.lanes.len());
        let mut all_fresh = true;
        for lane in self.lanes.iter_mut() {
            match lane.fresh.take() {
                Some(t) => out.push(LaneInput::Fresh(t)),
                None => {
                    all_fresh = false;
                    match &lane.last_za {
                        Some(t) => out.push(LaneInput::Stale(t.clone())),
                        None => out.push(LaneInput::Missing),
                    }
                }
            }
        }
        // Liveness gauges track this round's outcome per lane: fresh →
        // live, behind-but-alive → straggling, lost → dead.
        for (lane, input) in self.lanes.iter().zip(&out) {
            let (live, straggling, dead) = if !lane.alive {
                (0.0, 0.0, 1.0)
            } else if input.is_fresh() {
                (1.0, 0.0, 0.0)
            } else {
                (0.0, 1.0, 0.0)
            };
            Self::set_lane_gauges(self.sup.registry(), lane.peer, live,
                                  straggling, dead);
        }
        if all_fresh
            && matches!(self.sup.state(),
                        SessionState::Degraded | SessionState::Recovering)
        {
            self.sup.transition(SessionState::Running)?;
        }
        anyhow::ensure!(
            out.iter().any(|i| !matches!(i, LaneInput::Missing)),
            "round {round}: no activation statistics available on any \
             lane (every feature party lost before contributing)"
        );
        Ok(out)
    }

    /// Stage this round's derivative fan-out: one frame per lane under
    /// its negotiated codec, buffered for rejoin replay. Returns each
    /// lane's local derivative view (the dequantized round-trip for
    /// lossy codecs) in lane order — what the workset must cache.
    /// Staging is split from [`Self::send_staged`] so the caller can
    /// insert the cache entries *before* the (WAN-bound) sends — the
    /// cache-before-send overlap the paper's §3.1 relies on.
    pub fn stage_derivatives(&mut self, round: u64, dza: &Tensor)
                             -> anyhow::Result<Vec<Tensor>> {
        anyhow::ensure!(self.staged.is_empty(),
                        "stage_derivatives called with frames staged");
        let mut views = Vec::with_capacity(self.lanes.len());
        for lane in &self.lanes {
            let (msg, view) = outbound_stats(lane.codec, Lane::Derivative,
                                             round, dza.clone())?;
            self.staged.push(msg);
            views.push(view);
        }
        for (lane, msg) in self.lanes.iter_mut().zip(self.staged.iter()) {
            lane.resend.push_back((round, msg.clone()));
            if lane.resend.len() > RESEND_DEPTH {
                lane.resend.pop_front();
            }
        }
        Ok(views)
    }

    /// Send the staged derivative frames. The star's links are
    /// independent: one live lane takes the direct call (the two-party
    /// path, thread-free), more fan out on scoped sender threads so
    /// K−1 WAN transfers overlap. Send failures mark the lane lost in
    /// supervised mode and propagate otherwise.
    pub fn send_staged(&mut self, round: u64) -> anyhow::Result<()> {
        let mut frames = std::mem::take(&mut self.staged);
        anyhow::ensure!(frames.len() == self.lanes.len(),
                        "send_staged without staged frames");
        let live: Vec<usize> = (0..self.lanes.len())
            .filter(|&i| self.lanes[i].alive)
            .collect();
        let mut failures: Vec<(usize, anyhow::Error)> = Vec::new();
        if live.len() == 1 {
            let i = live[0];
            if let Err(e) = self.lanes[i].transport.send(
                frames.swap_remove(i)) {
                failures.push((i, e));
            }
        } else if !live.is_empty() {
            let lanes = &self.lanes;
            // Remove in descending index order so swap_remove never
            // disturbs a frame still to be taken.
            let results: Vec<(usize, Option<anyhow::Error>)> =
                std::thread::scope(|s| {
                    let mut handles = Vec::with_capacity(live.len());
                    for &i in live.iter().rev() {
                        let frame = frames.swap_remove(i);
                        let lane = &lanes[i];
                        handles.push((i, s.spawn(move || {
                            lane.transport.send(frame)
                        })));
                    }
                    handles
                        .into_iter()
                        .map(|(i, h)| {
                            (i, h.join()
                                .expect("derivative sender panicked")
                                .err())
                        })
                        .collect()
                });
            for (i, err) in results {
                if let Some(e) = err {
                    failures.push((i, e));
                }
            }
        }
        for (i, e) in failures {
            if !self.supervised {
                return Err(anyhow::anyhow!(
                    "sending derivative to {}: {e:#}",
                    self.lanes[i].peer
                ));
            }
            self.lose(i, round, &e);
        }
        Ok(())
    }

    /// [`Self::stage_derivatives`] + [`Self::send_staged`] in one call
    /// (callers that don't interleave a cache insert).
    pub fn fan_out(&mut self, round: u64, dza: &Tensor)
                   -> anyhow::Result<Vec<Tensor>> {
        let views = self.stage_derivatives(round, dza)?;
        self.send_staged(round)?;
        Ok(views)
    }

    /// Collect eval-lane activations for held-out batch `k` from the
    /// lanes in `participants` (see [`Self::current_lanes`]). A
    /// participant that times out or dies is removed from the list —
    /// its remaining eval frames are discarded by later drains — so the
    /// caller can tell whether the batch's partial sum stayed
    /// consistent across the eval walk. `round` attributes loss events.
    pub fn collect_eval(&mut self, participants: &mut Vec<usize>, k: u64,
                        round: u64) -> anyhow::Result<Vec<Tensor>> {
        let mut out = Vec::with_capacity(participants.len());
        let mut dropped: Vec<usize> = Vec::new();
        for &i in participants.iter() {
            if !self.lanes[i].alive {
                dropped.push(i);
                continue;
            }
            let deadline = self.straggler.map(|d| Instant::now() + d);
            let got = loop {
                let res = match deadline {
                    None => self.lanes[i].transport.recv().map(Some),
                    Some(dl) => match self.lanes[i].transport.try_recv() {
                        Ok(Some(m)) => Ok(Some(m)),
                        Ok(None) => {
                            if Instant::now() >= dl {
                                Ok(None)
                            } else {
                                std::thread::sleep(STRAGGLER_POLL);
                                continue;
                            }
                        }
                        Err(e) => Err(e),
                    },
                };
                match res {
                    Ok(Some(m)) => match m.into_plain()? {
                        Message::EvalActivation { round: r, tensor } => {
                            anyhow::ensure!(
                                r == k,
                                "eval lane skew on {}: {r} != {k}",
                                self.lanes[i].peer
                            );
                            break Some(tensor);
                        }
                        other => anyhow::bail!(
                            "expected eval activation from {}, got {:?}",
                            self.lanes[i].peer, other.tag()
                        ),
                    },
                    Ok(None) => {
                        log::warn!(
                            "[{}] eval batch {k} missed the straggler \
                             window — excluding the lane from this eval",
                            self.lanes[i].peer
                        );
                        break None;
                    }
                    Err(e) => {
                        if !self.supervised {
                            return Err(e);
                        }
                        self.lose(i, round, &e);
                        break None;
                    }
                }
            };
            match got {
                Some(t) => out.push(t),
                None => dropped.push(i),
            }
        }
        participants.retain(|i| !dropped.contains(i));
        Ok(out)
    }

    /// Orderly end: broadcast `Shutdown` on every lane (live or not —
    /// a dead socket just fails silently) and close the lifecycle.
    pub fn shutdown(&mut self) {
        for lane in &self.lanes {
            let _ = lane.transport.send(Message::Shutdown);
        }
        let _ = self.sup.transition(SessionState::Done);
    }

    // ---- internals ---------------------------------------------------------

    fn lose(&mut self, i: usize, round: u64, err: &anyhow::Error) {
        if !self.lanes[i].alive {
            return;
        }
        let peer = self.lanes[i].peer;
        self.lanes[i].alive = false;
        self.lanes[i].fresh = None;
        log::warn!("[{peer}] lane lost in round {round}: {err:#}");
        Self::set_lane_gauges(self.sup.registry(), peer, 0.0, 0.0, 1.0);
        self.sup.record(SessionEvent::PeerLost { party: peer, round });
        if matches!(self.sup.state(),
                    SessionState::Running | SessionState::Recovering) {
            let _ = self.sup.transition(SessionState::Degraded);
        }
    }

    /// Interpret one inbound frame on lane `i` during round `round`.
    fn consume(&mut self, i: usize, round: u64, msg: Message)
               -> anyhow::Result<()> {
        let peer = self.lanes[i].peer;
        match msg.into_plain()? {
            Message::Activation { round: r, tensor } => {
                if r == round {
                    let lane = &mut self.lanes[i];
                    lane.completed = r + 1;
                    lane.last_za = Some(tensor.clone());
                    lane.fresh = Some(tensor);
                } else if r < round && self.supervised {
                    // Catch-up from a behind lane: the round was
                    // already stepped on its stale statistics and its
                    // derivative already pushed at fan-out time, so the
                    // frame only refreshes the stale stand-in.
                    let lane = &mut self.lanes[i];
                    lane.completed = r + 1;
                    lane.last_za = Some(tensor);
                    self.catch_ups += 1;
                } else {
                    anyhow::bail!(
                        "protocol skew on {peer}: got activation {r}, \
                         expected {round}"
                    );
                }
            }
            Message::EvalActivation { .. } if self.supervised => {
                // A behind lane walking an eval boundary this side has
                // already passed or abandoned: eval is advisory, the
                // activation round clock is what must stay consistent.
                self.evals_discarded += 1;
            }
            other => anyhow::bail!(
                "unexpected message {:?} from {peer} in round {round}",
                other.tag()
            ),
        }
        Ok(())
    }

    /// Supervised-mode frame interpretation: a frame that fails to
    /// decode or violates the protocol (a chaos campaign's corrupted
    /// payload, a skewed round counter) is *discarded* — logged and
    /// counted — instead of tearing the whole session down. The lane
    /// stays alive and merely goes stale for the round; the next clean
    /// activation resynchronizes it through the normal fresh/catch-up
    /// paths. Unsupervised mode keeps the historic contract: the first
    /// protocol violation propagates. Returns whether the frame was
    /// actually consumed.
    fn consume_or_discard(&mut self, i: usize, round: u64, msg: Message)
                          -> anyhow::Result<bool> {
        match self.consume(i, round, msg) {
            Ok(()) => Ok(true),
            Err(e) if self.supervised => {
                self.discards += 1;
                log::warn!(
                    "[{}] discarding garbled frame in round {round}: \
                     {e:#}",
                    self.lanes[i].peer
                );
                Ok(false)
            }
            Err(e) => Err(e),
        }
    }

    /// Nonblocking drain of lane `i`: stash first, then whatever frames
    /// already arrived, stopping once this round's activation is in.
    fn drain_lane(&mut self, i: usize, round: u64) -> anyhow::Result<()> {
        loop {
            if !self.lanes[i].alive || self.lanes[i].fresh.is_some() {
                return Ok(());
            }
            if let Some(m) = self.lanes[i].stash.take() {
                self.consume_or_discard(i, round, m)?;
                continue;
            }
            match self.lanes[i].transport.try_recv() {
                Ok(Some(m)) => {
                    self.consume_or_discard(i, round, m)?;
                }
                Ok(None) => return Ok(()),
                Err(e) => {
                    if !self.supervised {
                        return Err(e);
                    }
                    self.lose(i, round, &e);
                    return Ok(());
                }
            }
        }
    }

    /// Historic blocking wait: one recv at a time per lane, errors
    /// propagate (unsupervised) or mark the lane lost (supervised). A
    /// discarded garbled frame ends the lane's wait for this round
    /// (stale step) rather than blocking on a replacement that will
    /// only arrive with the *next* round's traffic.
    fn wait_blocking(&mut self, round: u64) -> anyhow::Result<()> {
        for i in 0..self.lanes.len() {
            loop {
                if !self.lanes[i].alive || self.lanes[i].fresh.is_some() {
                    break;
                }
                if let Some(m) = self.lanes[i].stash.take() {
                    if !self.consume_or_discard(i, round, m)? {
                        break;
                    }
                    continue;
                }
                match self.lanes[i].transport.recv() {
                    Ok(m) => {
                        if !self.consume_or_discard(i, round, m)? {
                            break;
                        }
                    }
                    Err(e) => {
                        if !self.supervised {
                            return Err(e);
                        }
                        self.lose(i, round, &e);
                        break;
                    }
                }
            }
        }
        // No straggler budget bounds this round, and *every* lane is
        // dead: live lanes normally pace the rounds, but with none
        // left the label would free-run to max_rounds on stale
        // statistics in milliseconds. With an open re-admission point,
        // pace the degraded round and poll for rejoins instead.
        if self.readmission.is_some()
            && !self.lanes.iter().any(|l| l.alive)
        {
            let deadline = Instant::now() + DEGRADED_PACE;
            loop {
                self.process_rejoins(round)?;
                for i in 0..self.lanes.len() {
                    self.drain_lane(i, round)?;
                }
                let any_alive = self.lanes.iter().any(|l| l.alive);
                let missing_live = self
                    .lanes
                    .iter()
                    .any(|l| l.alive && l.fresh.is_none());
                if any_alive && !missing_live {
                    return Ok(()); // a lane rejoined and delivered
                }
                if Instant::now() >= deadline {
                    return Ok(());
                }
                std::thread::sleep(STRAGGLER_POLL);
            }
        }
        Ok(())
    }

    /// Bounded straggler wait: poll every missing lane (and the
    /// re-admission point) until all are fresh or the window closes.
    fn wait_deadline(&mut self, round: u64, wait: Duration)
                     -> anyhow::Result<()> {
        let deadline = Instant::now() + wait;
        loop {
            let missing_live = self
                .lanes
                .iter()
                .any(|l| l.alive && l.fresh.is_none());
            // With every lane dead (and a re-admission point open),
            // keep the full window anyway: it paces the degraded rounds
            // and gives a rejoining dialer a poll slot every round
            // instead of letting the label free-run to max_rounds on
            // stale statistics.
            let all_dead = !self.lanes.iter().any(|l| l.alive);
            if !missing_live && !(all_dead && self.readmission.is_some())
            {
                return Ok(());
            }
            if Instant::now() >= deadline {
                for i in 0..self.lanes.len() {
                    if self.lanes[i].alive && self.lanes[i].fresh.is_none()
                    {
                        let peer = self.lanes[i].peer;
                        log::warn!(
                            "[{peer}] round {round} missed the \
                             {wait:?} straggler window — stepping on \
                             cached stale statistics"
                        );
                        self.sup.record(SessionEvent::StragglerTimeout {
                            party: peer,
                            round,
                        });
                    }
                }
                if self.sup.state() == SessionState::Running {
                    self.sup.transition(SessionState::Degraded)?;
                }
                return Ok(());
            }
            self.process_rejoins(round)?;
            for i in 0..self.lanes.len() {
                self.drain_lane(i, round)?;
            }
            if self
                .lanes
                .iter()
                .any(|l| l.alive && l.fresh.is_none())
            {
                std::thread::sleep(STRAGGLER_POLL);
            }
        }
    }

    /// Admit any pending `Rejoin` dialers: session-level validation
    /// (known lane; ahead-of-us and zero-round claims are admitted
    /// loudly — the ack's resume round rewinds or fast-forwards the
    /// dialer), `RejoinAck` on the raw socket, transport wrap, bounded
    /// replay, lane swap. Frame-level rules (version, id ranges) and
    /// the epoch check already ran in the re-admission thread.
    fn process_rejoins(&mut self, round: u64) -> anyhow::Result<()> {
        let Some(adm) = &self.readmission else {
            return Ok(());
        };
        while let Some(mut req) = adm.try_take() {
            let Some(i) =
                self.lanes.iter().position(|l| l.peer == req.party)
            else {
                log::warn!(
                    "rejoin from {} refused: no such lane in this \
                     session", req.party
                );
                continue; // drop → dialer sees EOF
            };
            if req.last_round > round {
                // Only possible when this label restarted from a
                // checkpoint older than the dialer's progress: the
                // survivor ran ahead and must rewind. The ack's resume
                // round tells it where to.
                log::warn!(
                    "rejoin from {} claims {} completed rounds but the \
                     session is at round {round} — re-admitting with a \
                     rewind (label restarted from an older checkpoint?)",
                    req.party, req.last_round
                );
            } else if req.last_round == 0 && round > 0 {
                // A relaunched process that didn't restore a snapshot:
                // its local bottom-model state restarted from
                // initialization. Admit, but say so loudly — restarting
                // with `--resume <ckpt>` carries the model and AdaGrad
                // state across the crash instead.
                log::warn!(
                    "rejoin from {} reports zero completed rounds at \
                     session round {round} — if this is a relaunched \
                     process, its local model state restarted from \
                     initialization (run feature parties with \
                     --checkpoint-dir and restart with --resume to \
                     avoid this)", req.party
                );
            }
            let replay: Option<Message> = {
                let lane = &self.lanes[i];
                if lane.completed > req.last_round {
                    lane.resend
                        .iter()
                        .find(|(r, _)| *r == req.last_round)
                        .map(|(_, m)| m.clone())
                } else {
                    None
                }
            };
            let ack = Message::RejoinAck {
                party: req.party,
                parties: self.parties,
                epoch: self.sup.epoch(),
                resume_round: round,
                replays: replay.is_some() as u32,
            };
            if let Err(e) = send_bootstrap_frame(&mut req.stream, &ack) {
                log::warn!("rejoin ack to {} failed: {e:#}", req.party);
                continue;
            }
            if let Err(e) = req.stream.set_read_timeout(None) {
                log::warn!("rejoin wrap for {} failed: {e}", req.party);
                continue;
            }
            let t = match TcpTransport::from_stream(req.stream, self.wan) {
                Ok(t) => {
                    if self.v2 {
                        t.with_identity(LABEL_PARTY, req.party)
                    } else {
                        t
                    }
                }
                Err(e) => {
                    log::warn!("rejoin wrap for {} failed: {e:#}",
                               req.party);
                    continue;
                }
            };
            let t: Arc<dyn Transport> = Arc::new(t);
            let replays = replay.is_some() as u32;
            if let Some(m) = replay {
                if let Err(e) = t.send(m) {
                    log::warn!(
                        "derivative replay to {} failed: {e:#} — lane \
                         stays lost", req.party
                    );
                    continue;
                }
            }
            let lane = &mut self.lanes[i];
            let old = std::mem::replace(&mut lane.transport, t);
            // Accounting continuity across the transport swap: charge
            // the replacement's fresh cells with the dead transport's
            // final totals, then rebind the registry row (last bound
            // wins) so exporters keep reading live cells.
            match lane.transport.metrics() {
                Some(h) => {
                    h.charge(old.stats());
                    self.sup.registry()
                        .bind_link(LABEL_PARTY, lane.peer, &h);
                }
                None => log::warn!(
                    "[{}] rejoin transport exposes no metrics handles — \
                     pre-rejoin accounting dropped", lane.peer),
            }
            lane.alive = true;
            lane.fresh = None;
            lane.completed = round;
            lane.rejoins += 1;
            Self::set_lane_gauges(self.sup.registry(), req.party, 1.0,
                                  0.0, 0.0);
            log::info!(
                "{} rejoined the session: resumes at round {round} \
                 ({replays} replayed frames)", req.party
            );
            self.sup.record(SessionEvent::PeerRejoined {
                party: req.party,
                round,
            });
            if self.sup.state() == SessionState::Degraded {
                self.sup.transition(SessionState::Recovering)?;
            }
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::WanProfile;
    use crate::metrics::facade::{ChannelSink, EVENTS_CAP};
    use crate::session::inproc_star;

    fn t(v: f32) -> Tensor {
        Tensor::f32(vec![2], vec![v, v + 1.0])
    }

    fn act(round: u64, v: f32) -> Message {
        Message::Activation { round, tensor: t(v) }
    }

    fn cfg_k(k: usize, straggler_ms: u64) -> RunConfig {
        let mut cfg = RunConfig::quick();
        cfg.parties = k;
        cfg.wan = WanProfile::instant();
        cfg.straggler_wait_ms = straggler_ms;
        cfg
    }

    #[test]
    fn state_machine_validates_edges() {
        let mut s = Supervisor::new(7);
        assert_eq!(s.state(), SessionState::Joining);
        assert_eq!(s.epoch(), 7);
        s.transition(SessionState::Running).unwrap();
        s.transition(SessionState::Degraded).unwrap();
        s.transition(SessionState::Recovering).unwrap();
        s.transition(SessionState::Running).unwrap();
        // Self-transitions are no-ops.
        s.transition(SessionState::Running).unwrap();
        // Running cannot jump straight to Recovering.
        assert!(s.transition(SessionState::Recovering).is_err());
        s.transition(SessionState::Done).unwrap();
        // Done is terminal.
        assert!(s.transition(SessionState::Running).is_err());
    }

    #[test]
    fn events_record_and_cap() {
        let mut s = Supervisor::new(0);
        let e = SessionEvent::PeerLost { party: PartyId(2), round: 9 };
        assert_eq!(e.kind(), "peer_lost");
        assert_eq!(e.party(), Some(PartyId(2)));
        assert_eq!(e.round(), 9);
        s.record(e.clone());
        assert_eq!(s.events(), &[e]);
        let c = SessionEvent::CheckpointWritten {
            round: 5,
            path: "x".into(),
        };
        assert_eq!(c.party(), None);
        let f = SessionEvent::CheckpointFailed {
            round: 6,
            error: "disk full".into(),
        };
        assert_eq!(f.kind(), "checkpoint_failed");
        assert_eq!(f.party(), None);
        assert_eq!(f.round(), 6);
        s.record(f);
        for _ in 0..(EVENTS_CAP + 10) {
            s.record(c.clone());
        }
        assert_eq!(s.events().len(), EVENTS_CAP);
        assert!(s.dropped_events() > 0);
    }

    #[test]
    fn lane_set_publishes_into_a_shared_registry() {
        let cfg = cfg_k(3, 30);
        let (label_links, feature_links) = inproc_star(&cfg);
        let reg = Registry::new();
        let (tx, rx) = std::sync::mpsc::channel();
        let mut lanes = LaneSet::new(&cfg, &label_links, None)
            .with_registry(reg.clone());
        lanes.add_sink(Arc::new(ChannelSink::new(tx)));
        feature_links[0].transport.send(act(0, 1.0)).unwrap();
        feature_links[1].transport.send(act(0, 2.0)).unwrap();
        lanes.handshake(&cfg, None).unwrap();
        lanes.collect(0).unwrap();
        lanes.fan_out(0, &t(0.5)).unwrap();
        // The registry's LABEL→peer rows alias the very cells the lane
        // transports bump — no copying, no report threading.
        let rows = reg.link_rows();
        assert_eq!(rows.len(), 2);
        for ((peer, stats), row) in
            lanes.link_stats().iter().zip(rows.iter())
        {
            assert_eq!((row.src, row.dst), (LABEL_PARTY, *peer));
            assert_eq!(row.stats, *stats);
            assert!(row.stats.messages > 0);
        }
        // Round 1 stalls P2 past the straggler window: the timeout
        // event lands in the registry log, bumps its kind counter, and
        // fans out to the subscribed channel sink.
        feature_links[0].transport.send(act(1, 3.0)).unwrap();
        lanes.collect(1).unwrap();
        assert_eq!(reg.round(), 1);
        let expect = SessionEvent::StragglerTimeout { party: PartyId(2),
                                                      round: 1 };
        assert_eq!(reg.events(), vec![expect.clone()]);
        assert_eq!(rx.try_recv().unwrap(), expect);
        assert_eq!(
            reg.counter("celu_events_total{kind=\"straggler_timeout\"}")
                .get(),
            1);
        // take_events drains the shared registry, exactly as the old
        // supervisor-private log behaved.
        assert_eq!(lanes.take_events().len(), 1);
        assert!(reg.events().is_empty());
    }

    #[test]
    fn lane_liveness_gauges_track_live_straggling_dead() {
        let g = |reg: &Registry, family: &str, peer: u16| {
            reg.gauge(&format!("{family}{{peer=\"{peer}\"}}")).get()
        };
        let cfg = cfg_k(3, 30);
        let (label_links, feature_links) = inproc_star(&cfg);
        let reg = Registry::new();
        let mut lanes = LaneSet::new(&cfg, &label_links, None)
            .with_registry(reg.clone());
        // Pre-registered at bind time: every lane starts live.
        assert_eq!(g(&reg, "celu_lane_live", 1), 1.0);
        assert_eq!(g(&reg, "celu_lane_live", 2), 1.0);
        assert_eq!(g(&reg, "celu_lane_dead", 1), 0.0);
        feature_links[0].transport.send(act(0, 1.0)).unwrap();
        feature_links[1].transport.send(act(0, 2.0)).unwrap();
        lanes.handshake(&cfg, None).unwrap();
        lanes.collect(0).unwrap();
        assert_eq!(g(&reg, "celu_lane_live", 1), 1.0);
        assert_eq!(g(&reg, "celu_lane_live", 2), 1.0);
        // Round 1: P1 delivers, P2 misses the straggler window → its
        // lane shows straggling, P1 stays live.
        feature_links[0].transport.send(act(1, 3.0)).unwrap();
        lanes.collect(1).unwrap();
        assert_eq!(g(&reg, "celu_lane_live", 1), 1.0);
        assert_eq!(g(&reg, "celu_lane_straggling", 1), 0.0);
        assert_eq!(g(&reg, "celu_lane_straggling", 2), 1.0);
        assert_eq!(g(&reg, "celu_lane_dead", 2), 0.0);
        // P2's endpoint dies → the next collect flips it to dead.
        feature_links[0].transport.send(act(2, 4.0)).unwrap();
        drop(feature_links);
        lanes.collect(2).unwrap();
        assert_eq!(g(&reg, "celu_lane_dead", 2), 1.0);
        assert_eq!(g(&reg, "celu_lane_live", 2), 0.0);
        assert_eq!(g(&reg, "celu_lane_straggling", 2), 0.0);
    }

    #[test]
    fn session_epoch_is_deterministic_and_seed_sensitive() {
        assert_eq!(session_epoch(42), session_epoch(42));
        assert_ne!(session_epoch(42), session_epoch(43));
    }

    #[test]
    fn unsupervised_collect_matches_legacy_blocking_behaviour() {
        let cfg = cfg_k(3, 0);
        let (label_links, feature_links) = inproc_star(&cfg);
        let mut lanes = LaneSet::new(&cfg, &label_links, None);
        assert!(!lanes.supervised);
        // Features speak first (identity config → no Hello): stash the
        // first frames via handshake, then collect round 0.
        feature_links[0].transport.send(act(0, 1.0)).unwrap();
        feature_links[1].transport.send(act(0, 2.0)).unwrap();
        lanes.handshake(&cfg, None).unwrap();
        assert_eq!(lanes.state(), SessionState::Running);
        let inputs = lanes.collect(0).unwrap();
        assert!(inputs.iter().all(|i| i.is_fresh()));
        // A dropped feature endpoint propagates as an error, exactly
        // like the historic loop.
        drop(feature_links);
        assert!(lanes.collect(1).is_err());
    }

    #[test]
    fn straggler_timeout_steps_on_stale_statistics() {
        let cfg = cfg_k(3, 30);
        let (label_links, feature_links) = inproc_star(&cfg);
        let mut lanes = LaneSet::new(&cfg, &label_links, None);
        feature_links[0].transport.send(act(0, 1.0)).unwrap();
        feature_links[1].transport.send(act(0, 2.0)).unwrap();
        lanes.handshake(&cfg, None).unwrap();
        let inputs = lanes.collect(0).unwrap();
        assert!(inputs.iter().all(|i| i.is_fresh()));
        let views = lanes.fan_out(0, &t(0.5)).unwrap();
        assert_eq!(views.len(), 2);
        // Round 1: only P1 shows up; P2's lane must time out and fall
        // back to its round-0 activation.
        feature_links[0].transport.send(act(1, 3.0)).unwrap();
        let inputs = lanes.collect(1).unwrap();
        assert!(inputs[0].is_fresh());
        match &inputs[1] {
            LaneInput::Stale(z) => {
                assert_eq!(z.as_f32().unwrap(), &[2.0, 3.0]);
            }
            other => panic!("expected stale input, got {other:?}"),
        }
        assert_eq!(lanes.state(), SessionState::Degraded);
        let events = lanes.take_events();
        assert!(events.iter().any(|e| matches!(
            e,
            SessionEvent::StragglerTimeout { party: PartyId(2), round: 1 }
        )));
        // The straggler catches up: its late round-1 frame is drained
        // as catch-up, and round 2 is fresh again → Running.
        lanes.fan_out(1, &t(0.6)).unwrap();
        feature_links[1].transport.send(act(1, 9.0)).unwrap();
        feature_links[0].transport.send(act(2, 4.0)).unwrap();
        feature_links[1].transport.send(act(2, 5.0)).unwrap();
        let inputs = lanes.collect(2).unwrap();
        assert!(inputs.iter().all(|i| i.is_fresh()));
        assert_eq!(lanes.catch_ups(), 1);
        assert_eq!(lanes.state(), SessionState::Running);
    }

    #[test]
    fn supervised_peer_loss_degrades_instead_of_erroring() {
        let cfg = cfg_k(3, 20);
        let (label_links, feature_links) = inproc_star(&cfg);
        let mut lanes = LaneSet::new(&cfg, &label_links, None);
        feature_links[0].transport.send(act(0, 1.0)).unwrap();
        feature_links[1].transport.send(act(0, 2.0)).unwrap();
        lanes.handshake(&cfg, None).unwrap();
        lanes.collect(0).unwrap();
        lanes.fan_out(0, &t(0.5)).unwrap();
        for l in &feature_links {
            l.transport.recv().unwrap();
        }
        // Kill P2's endpoint entirely.
        let p1 = feature_links.into_iter().next().unwrap();
        p1.transport.send(act(1, 3.0)).unwrap();
        let inputs = lanes.collect(1).unwrap();
        assert!(inputs[0].is_fresh());
        assert!(matches!(inputs[1], LaneInput::Stale(_)));
        assert_eq!(lanes.state(), SessionState::Degraded);
        let events = lanes.take_events();
        assert!(events.iter().any(|e| matches!(
            e,
            SessionEvent::PeerLost { party: PartyId(2), .. }
        )));
        // Fan-out keeps serving the live lane.
        lanes.fan_out(1, &t(0.7)).unwrap();
        assert_eq!(p1.transport.recv().unwrap().round(), 1);
        // Stats for the dead lane are still reported.
        assert_eq!(lanes.link_stats().len(), 2);
    }

    #[test]
    fn collect_refuses_future_rounds_and_unknown_frames() {
        let cfg = cfg_k(2, 0);
        let (label_links, feature_links) = inproc_star(&cfg);
        let mut lanes = LaneSet::new(&cfg, &label_links, None);
        feature_links[0].transport.send(act(3, 1.0)).unwrap();
        lanes.handshake(&cfg, None).unwrap();
        let e = lanes.collect(0).unwrap_err().to_string();
        assert!(e.contains("protocol skew"), "{e}");
        let (label_links, feature_links) = inproc_star(&cfg);
        let mut lanes = LaneSet::new(&cfg, &label_links, None);
        feature_links[0]
            .transport
            .send(Message::EvalAck { round: 0 })
            .unwrap();
        lanes.handshake(&cfg, None).unwrap();
        let e = lanes.collect(0).unwrap_err().to_string();
        assert!(e.contains("unexpected message"), "{e}");
    }

    #[test]
    fn supervised_collect_discards_garbled_frames_and_keeps_the_lane() {
        let cfg = cfg_k(3, 30);
        let (label_links, feature_links) = inproc_star(&cfg);
        let mut lanes = LaneSet::new(&cfg, &label_links, None);
        // P1 opens with a protocol violation — a future-round
        // activation, exactly what a corrupted-but-decodable chaos
        // frame looks like; P2 is clean. Supervised mode must discard
        // the frame, not tear the session down.
        feature_links[0].transport.send(act(5, 1.0)).unwrap();
        feature_links[1].transport.send(act(0, 2.0)).unwrap();
        lanes.handshake(&cfg, None).unwrap();
        let inputs = lanes.collect(0).unwrap();
        assert!(matches!(inputs[0], LaneInput::Missing),
                "garbled opener must leave the lane without stats");
        assert!(inputs[1].is_fresh());
        assert_eq!(lanes.discards(), 1);
        // The lane survives the discard: its next clean frame is
        // consumed fresh and the counter stays put.
        feature_links[0].transport.send(act(1, 3.0)).unwrap();
        feature_links[1].transport.send(act(1, 4.0)).unwrap();
        let inputs = lanes.collect(1).unwrap();
        assert!(inputs.iter().all(|i| i.is_fresh()));
        assert_eq!(lanes.discards(), 1);
    }

    #[test]
    fn lane_input_accessors() {
        assert!(LaneInput::Fresh(t(0.0)).is_fresh());
        assert!(!LaneInput::Stale(t(0.0)).is_fresh());
        assert!(LaneInput::Missing.tensor().is_none());
        assert!(LaneInput::Stale(t(1.0)).tensor().is_some());
    }
}

#[cfg(test)]
mod lifecycle_tests {
    //! End-to-end lifecycle coverage over real loopback TCP: mid-run
    //! re-Join with in-flight replay, and the checkpoint → restart →
    //! Rejoin acceptance property (post-restart per-link totals equal
    //! an uninterrupted session's over the same rounds).

    use super::*;
    use crate::session::bootstrap::{inproc_mesh, rejoin_dial,
                                    MeshBootstrap, SessionDialer,
                                    SessionListener};
    use crate::session::checkpoint::{FeatureSnapshot, LinkCodecState};
    use crate::transport::fault::{FaultPlan, FaultTransport};

    fn t(v: f32) -> Tensor {
        Tensor::f32(vec![2], vec![v, v + 1.0])
    }

    fn act(round: u64) -> Message {
        Message::Activation { round, tensor: t(round as f32) }
    }

    fn sub(a: LinkStats, b: LinkStats) -> (u64, u64, u64) {
        (a.bytes - b.bytes, a.raw_bytes - b.raw_bytes,
         a.messages - b.messages)
    }

    fn triple(s: LinkStats) -> (u64, u64, u64) {
        (s.bytes, s.raw_bytes, s.messages)
    }

    #[test]
    fn midrun_rejoin_replays_the_inflight_round() {
        let mut cfg = RunConfig::quick();
        cfg.parties = 2;
        cfg.wan = crate::config::WanProfile::instant();
        cfg.straggler_wait_ms = 500;
        let epoch = session_epoch(cfg.seed);
        let listener = SessionListener::bind("127.0.0.1:0")
            .unwrap()
            .with_timeout(Duration::from_secs(10));
        let addr = listener.local_addr().unwrap().to_string();
        let label = std::thread::spawn({
            let cfg = cfg.clone();
            move || listener.establish_supervised(&cfg)
        });
        let feature_links = SessionDialer::new(&addr, PartyId(1))
            .with_timeout(Duration::from_secs(10))
            .establish(&cfg)
            .unwrap();
        let (links, readmission, _e, _s) = label.join().unwrap().unwrap();
        let mut lanes = LaneSet::new(&cfg, &links, Some(readmission));
        lanes.handshake(&cfg, None).unwrap();

        // Round 0 completes normally.
        let ft = feature_links[0].transport.clone();
        ft.send(act(0)).unwrap();
        assert!(lanes.collect(0).unwrap()[0].is_fresh());
        lanes.fan_out(0, &t(0.5)).unwrap();
        assert_eq!(ft.recv().unwrap().round(), 0);
        // The feature dies right after sending its round-1 activation —
        // the in-flight round.
        ft.send(act(1)).unwrap();
        drop(ft);
        drop(feature_links);
        // The label still consumes the in-flight activation, steps, and
        // buffers Derivative{1} for replay; the dead socket surfaces on
        // the next round at the latest.
        assert!(lanes.collect(1).unwrap()[0].is_fresh());
        lanes.fan_out(1, &t(0.6)).unwrap();
        let inputs = lanes.collect(2).unwrap();
        assert!(matches!(inputs[0], LaneInput::Stale(_)),
                "dead lane must degrade to stale stats");
        assert_eq!(lanes.state(), SessionState::Degraded);
        lanes.fan_out(2, &t(0.7)).unwrap();

        // The party comes back: Rejoin with last_round = 1 (its
        // in-flight round) must be acked with exactly one replay —
        // Derivative{1} — then lock-step resumes at the current round.
        let rejoiner = std::thread::spawn({
            let addr = addr.clone();
            let cfg = cfg.clone();
            move || -> anyhow::Result<u64> {
                let (transport, resume, replays) = rejoin_dial(
                    &addr, PartyId(1), &cfg, epoch, 1,
                    Duration::from_secs(10))?;
                anyhow::ensure!(replays == 1, "expected 1 replay, got \
                                               {replays}");
                match transport.recv()?.into_plain()? {
                    Message::Derivative { round, .. } => {
                        anyhow::ensure!(round == 1,
                                        "replay carries round {round}");
                    }
                    other => anyhow::bail!("unexpected replay {:?}",
                                           other.tag()),
                }
                transport.send(act(resume))?;
                match transport.recv()?.into_plain()? {
                    Message::Derivative { round, .. } => {
                        anyhow::ensure!(round == resume, "post-rejoin \
                                                          skew");
                    }
                    other => anyhow::bail!("unexpected {:?}",
                                           other.tag()),
                }
                Ok(resume)
            }
        });
        // Round 3: the rejoin is admitted inside the collect (the
        // re-admission point is polled during the straggler wait) and
        // the fresh activation lands in the same round.
        let inputs = lanes.collect(3).unwrap();
        assert!(inputs[0].is_fresh(),
                "rejoined lane must deliver fresh stats");
        lanes.fan_out(3, &t(0.8)).unwrap();
        let resume = rejoiner.join().unwrap().unwrap();
        assert_eq!(resume, 3);
        assert_eq!(lanes.total_rejoins(), 1);
        assert_eq!(lanes.state(), SessionState::Running);
        let events = lanes.take_events();
        assert!(events.iter().any(|e| matches!(
            e, SessionEvent::PeerLost { party: PartyId(1), .. })));
        assert!(events.iter().any(|e| matches!(
            e,
            SessionEvent::PeerRejoined { party: PartyId(1), round: 3 }
        )));
        // Accounting carries across the transport swap: Derivative{0}
        // on the first socket, the replay and Derivative{3} on the
        // fresh one (Derivative{1}'s send races the peer's death and
        // may count on either side of it).
        let (_, stats) = lanes.link_stats()[0];
        assert!(stats.messages >= 3, "carried stats lost: {stats:?}");
    }

    /// Run one TCP feature party for rounds `0..total`, transparently
    /// rejoining through a label restart. Returns the post-restart
    /// segment of its sender-side accounting: the fresh transport's
    /// stats when a rejoin happened, else `final − at(snapshot_at)`.
    fn tcp_feature_loop(addr: String, party: PartyId, cfg: RunConfig,
                        total: u64, snapshot_at: u64)
                        -> anyhow::Result<(u64, u64, u64)> {
        let (link, start) = SessionDialer::new(&addr, party)
            .with_timeout(Duration::from_secs(10))
            .establish_resumable(&cfg)?;
        anyhow::ensure!(start == 0, "fresh join resumed at {start}");
        let codec = compress::negotiate(cfg.codec_for(party.0),
                                        link.peer_codecs);
        let epoch = session_epoch(cfg.seed);
        let mut transport = link.transport.clone();
        let mut base: Option<LinkStats> = None;
        let mut rejoined = false;
        let mut round = 0u64;
        while round < total {
            if round == snapshot_at && !rejoined && base.is_none() {
                base = Some(transport.stats());
            }
            let za = t(party.0 as f32 + round as f32);
            let (msg, _) =
                outbound_stats(codec, Lane::Activation, round, za)?;
            let sent = transport.send(msg);
            let ok = match sent {
                Ok(()) => match transport.recv() {
                    Ok(m) => match m.into_plain()? {
                        Message::Derivative { round: r, .. } => {
                            anyhow::ensure!(r == round, "skew on \
                                                         {party}: {r}");
                            true
                        }
                        other => anyhow::bail!("unexpected {:?}",
                                               other.tag()),
                    },
                    Err(_) => false,
                },
                Err(_) => false,
            };
            if ok {
                round += 1;
                continue;
            }
            // The label died; rejoin through the restarted listener.
            let (tr, resume, replays) = rejoin_dial(
                &addr, party, &cfg, epoch, round,
                Duration::from_secs(10))?;
            anyhow::ensure!(replays == 0,
                            "restart must not replay ({replays})");
            transport = tr;
            rejoined = true;
            round = resume;
        }
        loop {
            match transport.recv() {
                Ok(Message::Shutdown) | Err(_) => break,
                Ok(_) => {}
            }
        }
        Ok(if rejoined {
            triple(transport.stats())
        } else {
            sub(transport.stats(), base.expect("boundary snapshot"))
        })
    }

    /// One supervised label segment over `lanes`: rounds `from..to`.
    fn label_segment(cfg: &RunConfig, lanes: &mut LaneSet, from: u64,
                     to: u64) -> anyhow::Result<()> {
        for round in from..to {
            let inputs = lanes.collect(round)?;
            anyhow::ensure!(inputs.iter().all(|i| i.is_fresh()),
                            "unexpected degradation at round {round}");
            let zs: Vec<Tensor> = inputs
                .iter()
                .filter_map(|i| i.tensor().cloned())
                .collect();
            let zsum = Tensor::sum_f32(&zs)?;
            lanes.fan_out(round, &zsum)?;
        }
        Ok(())
    }

    /// Acceptance: checkpoint → restart → Rejoin produces the same
    /// per-link totals for post-restart rounds as an uninterrupted
    /// session over those rounds. Protocol-level (no model), K = 3,
    /// mixed codecs (P1 fp16 via join-time pre-negotiation, pinned
    /// from the snapshot after the restart).
    #[test]
    fn checkpoint_restart_rejoin_matches_uninterrupted_totals() {
        const N: u64 = 8;
        const M: u64 = 4;
        let mut cfg = RunConfig::quick();
        cfg.parties = 3;
        cfg.wan = crate::config::WanProfile::instant();
        cfg.straggler_wait_ms = 500;
        cfg.compress = CodecKind::Identity;
        cfg.party_compress = vec![(1, CodecKind::Fp16)];
        cfg.validate().unwrap();

        let run_features = |addr: &str| {
            [1u16, 2]
                .iter()
                .map(|&p| {
                    let addr = addr.to_string();
                    let cfg = cfg.clone();
                    std::thread::spawn(move || {
                        tcp_feature_loop(addr, PartyId(p), cfg, N, M)
                    })
                })
                .collect::<Vec<_>>()
        };

        // ---- phase A: uninterrupted reference -------------------------------
        let listener = SessionListener::bind("127.0.0.1:0")
            .unwrap()
            .with_timeout(Duration::from_secs(10));
        let addr_a = listener.local_addr().unwrap().to_string();
        let features_a = run_features(&addr_a);
        let (links, readmission, _e, _s) =
            listener.establish_supervised(&cfg).unwrap();
        let mut lanes = LaneSet::new(&cfg, &links, Some(readmission));
        lanes.handshake(&cfg, None).unwrap();
        label_segment(&cfg, &mut lanes, 0, M).unwrap();
        let at_m = lanes.link_stats();
        label_segment(&cfg, &mut lanes, M, N).unwrap();
        lanes.shutdown();
        let final_a = lanes.link_stats();
        let label_post_a: Vec<(u16, (u64, u64, u64))> = final_a
            .iter()
            .zip(&at_m)
            .map(|((p, f), (_, m))| (p.0, sub(*f, *m)))
            .collect();
        let mut feature_post_a = Vec::new();
        for h in features_a {
            feature_post_a.push(h.join().unwrap().unwrap());
        }

        // ---- phase B: checkpoint at M, crash, restart, Rejoin ---------------
        let listener = SessionListener::bind("127.0.0.1:0")
            .unwrap()
            .with_timeout(Duration::from_secs(10));
        let addr_b = listener.local_addr().unwrap().to_string();
        let features_b = run_features(&addr_b);
        let (links, readmission, epoch, _s) =
            listener.establish_supervised(&cfg).unwrap();
        let mut lanes = LaneSet::new(&cfg, &links, Some(readmission));
        lanes.handshake(&cfg, None).unwrap();
        label_segment(&cfg, &mut lanes, 0, M).unwrap();
        // "Checkpoint": the codec states a real snapshot would carry.
        let pinned: Vec<LinkCodecState> = lanes.codec_states();
        // "Crash": drop lanes, re-admission point, sockets — no
        // Shutdown anywhere. The features are left mid-flight.
        drop(lanes);
        // "Restart": a fresh process binds the same address in resume
        // mode; both features fall back to Rejoin and fast-forward.
        let listener = {
            let deadline = Instant::now() + Duration::from_secs(5);
            loop {
                match SessionListener::bind(&addr_b) {
                    Ok(l) => break l,
                    Err(e) => {
                        if Instant::now() >= deadline {
                            panic!("rebind of {addr_b} failed: {e:#}");
                        }
                        std::thread::sleep(Duration::from_millis(25));
                    }
                }
            }
        }
        .with_timeout(Duration::from_secs(10))
        .with_resume(epoch, M);
        let (links, readmission, _e, start) =
            listener.establish_supervised(&cfg).unwrap();
        assert_eq!(start, M);
        let mut lanes = LaneSet::new(&cfg, &links, Some(readmission));
        lanes.handshake(&cfg, Some(&pinned)).unwrap();
        label_segment(&cfg, &mut lanes, M, N).unwrap();
        lanes.shutdown();
        let label_post_b: Vec<(u16, (u64, u64, u64))> = lanes
            .link_stats()
            .iter()
            .map(|(p, s)| (p.0, triple(*s)))
            .collect();
        let mut feature_post_b = Vec::new();
        for h in features_b {
            feature_post_b.push(h.join().unwrap().unwrap());
        }

        // ---- the acceptance equality ----------------------------------------
        assert_eq!(label_post_b, label_post_a,
                   "label-side post-restart per-link totals diverged");
        assert_eq!(feature_post_b, feature_post_a,
                   "feature-side post-restart per-link totals diverged");
        // Sanity: the fp16 lane genuinely compressed post-restart too.
        let p1 = feature_post_b[0];
        assert!(p1.0 < p1.1,
                "fp16 lane not compressed post-restart: {p1:?}");
    }

    /// Every `FaultPlan` injection point, driven against the
    /// supervisor's straggler / catch-up / peer-lost machinery on an
    /// in-proc K = 3 mesh: a delayed frame straggles then catches up,
    /// a dropped frame and a one-way partition each stale exactly
    /// their round, and a kill degrades the session for good.
    #[test]
    fn fault_injections_drive_straggler_and_peer_lost_paths() {
        const ROUNDS: u64 = 5;
        let mut cfg = RunConfig::quick();
        cfg.parties = 3;
        cfg.wan = crate::config::WanProfile::instant();
        cfg.straggler_wait_ms = 500;
        cfg.compress = CodecKind::Identity;
        cfg.validate().unwrap();
        let (label_bs, feature_bs) = inproc_mesh(&cfg);

        // P1 straggles at round 1 (delayed past the window, catching
        // up inside round 2) and is one-way partitioned for round 3;
        // P2's round-2 activation is lost on the wire and the party is
        // killed outright at round 4.
        let plans = [
            FaultPlan::new(11).delay_ms(1, 700).partition_rounds(3, 4),
            FaultPlan::new(22).drop_frame(2).kill_at_round(4),
        ];
        let mut features = Vec::new();
        for (bs, plan) in feature_bs.into_iter().zip(plans) {
            features.push(std::thread::spawn({
                let cfg = cfg.clone();
                move || -> anyhow::Result<()> {
                    let links = bs.establish(&cfg)?;
                    let ft: Arc<dyn Transport> = Arc::new(
                        FaultTransport::new(links[0].transport.clone(),
                                            plan));
                    for round in 0..ROUNDS {
                        if ft.send(act(round)).is_err() {
                            // The injected kill; dropping the links
                            // surfaces the death on the label side.
                            return Ok(());
                        }
                        match ft.recv() {
                            Ok(m) => anyhow::ensure!(
                                m.round() == round, "skew at {round}"),
                            Err(_) => return Ok(()),
                        }
                    }
                    loop {
                        match ft.recv() {
                            Ok(Message::Shutdown) | Err(_) => {
                                return Ok(())
                            }
                            Ok(_) => {}
                        }
                    }
                }
            }));
        }

        let links = label_bs.establish(&cfg).unwrap();
        let mut lanes = LaneSet::new(&cfg, &links, None);
        lanes.handshake(&cfg, None).unwrap();
        let mut freshness = Vec::new();
        for round in 0..ROUNDS {
            let inputs = lanes.collect(round).unwrap();
            freshness.push((inputs[0].is_fresh(),
                            inputs[1].is_fresh()));
            let zs: Vec<Tensor> = inputs
                .iter()
                .filter_map(|i| i.tensor().cloned())
                .collect();
            lanes.fan_out(round, &Tensor::sum_f32(&zs).unwrap())
                 .unwrap();
        }
        assert_eq!(freshness, vec![
            (true, true),  // round 0: clean
            (false, true), // round 1: P1 delayed past the window
            (true, false), // round 2: P1 caught up; P2's frame dropped
            (false, true), // round 3: P1 one-way partitioned out
            (true, false), // round 4: P2 killed
        ]);
        assert!(lanes.catch_ups() >= 1,
                "the delayed frame never caught up");
        assert_eq!(lanes.state(), SessionState::Degraded);
        lanes.shutdown();
        let events = lanes.take_events();
        let straggled = |party: u16, round: u64| {
            events.iter().any(|e| {
                e.kind() == "straggler_timeout"
                    && e.party() == Some(PartyId(party))
                    && e.round() == round
            })
        };
        assert!(straggled(1, 1), "missing straggler: {events:?}");
        assert!(straggled(2, 2), "missing straggler: {events:?}");
        assert!(straggled(1, 3), "missing straggler: {events:?}");
        assert!(events.iter().any(|e| matches!(
            e, SessionEvent::PeerLost { party: PartyId(2), .. })),
            "the killed party was never declared lost: {events:?}");
        for h in features {
            h.join().unwrap().unwrap();
        }
    }

    /// Acceptance (symmetric fault tolerance): a `FaultPlan`-injected
    /// kill of a feature party, restarted from its `FeatureSnapshot`
    /// on disk, completes the session with round-count parity and
    /// byte-identical surviving links vs an undisturbed reference run.
    #[test]
    fn faultplan_kill_and_snapshot_resume_match_the_reference_run() {
        const N: u64 = 8;
        const KILL: u64 = 4;

        /// The victim: a feature party that checkpoints every round
        /// boundary, dies at the plan's kill point, reloads its latest
        /// snapshot and rejoins claiming the snapshot's round. Returns
        /// the resume round and the fresh transport's sender totals.
        fn victim_loop(addr: String, cfg: RunConfig, dir: String)
                       -> anyhow::Result<(u64, (u64, u64, u64))> {
            let party = PartyId(1);
            let links = SessionDialer::new(&addr, party)
                .with_timeout(Duration::from_secs(10))
                .establish(&cfg)?;
            let codec = compress::negotiate(cfg.codec_for(party.0),
                                            links[0].peer_codecs);
            let epoch = session_epoch(cfg.seed);
            let plan = FaultPlan::new(0xC4A05)
                .kill_within(KILL, KILL + 1);
            anyhow::ensure!(plan.kill_round() == Some(KILL),
                            "kill_within must resolve to round {KILL}");
            let faulted: Arc<dyn Transport> = Arc::new(
                FaultTransport::new(links[0].transport.clone(), plan));
            let mut completed = 0u64;
            let mut last_path = String::new();
            loop {
                let za = t(party.0 as f32 + completed as f32);
                let (msg, _) = outbound_stats(codec, Lane::Activation,
                                              completed, za)?;
                if faulted.send(msg).is_err() {
                    break; // the injected kill point
                }
                match faulted.recv()?.into_plain()? {
                    Message::Derivative { round: r, .. } => {
                        anyhow::ensure!(r == completed, "skew: {r}");
                    }
                    other => anyhow::bail!("unexpected {:?}",
                                           other.tag()),
                }
                completed += 1;
                // Round-boundary snapshot (checkpoint_every = 1).
                last_path = FeatureSnapshot {
                    epoch,
                    round: completed,
                    parties: cfg.parties as u16,
                    party: party.0,
                    codec,
                    params: vec![t(completed as f32)],
                    accs: vec![t(0.5 * completed as f32)],
                }
                .save(&dir)?;
            }
            anyhow::ensure!(completed == KILL,
                            "killed at {completed}, planned {KILL}");
            // "Restart": recover state from disk and Rejoin with the
            // snapshot's round claim. The old socket is held open
            // until the label's lane swap drops its end — a hung
            // process's lane is silent, not dead, so every interim
            // round pays the full straggler window that also polls
            // the re-admission point.
            let snap = FeatureSnapshot::load(&last_path)?;
            anyhow::ensure!(snap.round == KILL && snap.epoch == epoch
                            && snap.party == party.0
                            && snap.codec == codec,
                            "snapshot header diverged from the run");
            anyhow::ensure!(snap.params == vec![t(KILL as f32)],
                            "restored params diverged");
            let (fresh, resume, replays) = rejoin_dial(
                &addr, party, &cfg, epoch, snap.round,
                Duration::from_secs(10))?;
            anyhow::ensure!(resume >= KILL && resume < N,
                            "resumed at {resume}, outside \
                             [{KILL}, {N})");
            for _ in 0..replays {
                let _ = fresh.recv()?; // stale in-flight derivatives
            }
            for round in resume..N {
                let za = t(party.0 as f32 + round as f32);
                let (msg, _) = outbound_stats(snap.codec,
                                              Lane::Activation, round,
                                              za)?;
                fresh.send(msg)?;
                match fresh.recv()?.into_plain()? {
                    Message::Derivative { round: r, .. } => {
                        anyhow::ensure!(r == round,
                                        "post-resume skew: {r}");
                    }
                    other => anyhow::bail!("unexpected {:?}",
                                           other.tag()),
                }
            }
            loop {
                match fresh.recv() {
                    Ok(Message::Shutdown) | Err(_) => break,
                    Ok(_) => {}
                }
            }
            drop(links);
            Ok((resume, triple(fresh.stats())))
        }

        let mut cfg = RunConfig::quick();
        cfg.parties = 3;
        cfg.wan = crate::config::WanProfile::instant();
        cfg.straggler_wait_ms = 500;
        cfg.compress = CodecKind::Identity;
        cfg.party_compress = vec![(1, CodecKind::Fp16)];
        cfg.validate().unwrap();
        let dir = std::env::temp_dir().join(format!(
            "celu_fault_resume_{}", std::process::id()));
        let _ = std::fs::remove_dir_all(&dir);
        std::fs::create_dir_all(&dir).unwrap();

        // ---- reference: undisturbed K = 3 run -------------------------------
        let listener = SessionListener::bind("127.0.0.1:0")
            .unwrap()
            .with_timeout(Duration::from_secs(10));
        let addr = listener.local_addr().unwrap().to_string();
        let features: Vec<_> = [1u16, 2]
            .iter()
            .map(|&p| {
                let addr = addr.clone();
                let cfg = cfg.clone();
                std::thread::spawn(move || {
                    tcp_feature_loop(addr, PartyId(p), cfg, N, 0)
                })
            })
            .collect();
        let (links, readmission, _e, _s) =
            listener.establish_supervised(&cfg).unwrap();
        let mut lanes = LaneSet::new(&cfg, &links, Some(readmission));
        lanes.handshake(&cfg, None).unwrap();
        label_segment(&cfg, &mut lanes, 0, N).unwrap();
        lanes.shutdown();
        let label_ref: Vec<(u16, (u64, u64, u64))> = lanes
            .link_stats()
            .iter()
            .map(|(p, s)| (p.0, triple(*s)))
            .collect();
        let mut feature_ref = Vec::new();
        for h in features {
            feature_ref.push(h.join().unwrap().unwrap());
        }

        // ---- fault run: P1 killed at round KILL, resumed from disk ----------
        let listener = SessionListener::bind("127.0.0.1:0")
            .unwrap()
            .with_timeout(Duration::from_secs(10));
        let addr = listener.local_addr().unwrap().to_string();
        let h1 = std::thread::spawn({
            let addr = addr.clone();
            let cfg = cfg.clone();
            let dir = dir.to_string_lossy().into_owned();
            move || victim_loop(addr, cfg, dir)
        });
        let h2 = std::thread::spawn({
            let addr = addr.clone();
            let cfg = cfg.clone();
            move || tcp_feature_loop(addr, PartyId(2), cfg, N, 0)
        });
        let (links, readmission, _e, _s) =
            listener.establish_supervised(&cfg).unwrap();
        let mut lanes = LaneSet::new(&cfg, &links, Some(readmission));
        lanes.handshake(&cfg, None).unwrap();
        // No freshness assert: the victim's lane goes silent between
        // the kill and its rejoin.
        for round in 0..N {
            let inputs = lanes.collect(round).unwrap();
            let zs: Vec<Tensor> = inputs
                .iter()
                .filter_map(|i| i.tensor().cloned())
                .collect();
            lanes.fan_out(round, &Tensor::sum_f32(&zs).unwrap())
                 .unwrap();
        }
        assert_eq!(lanes.total_rejoins(), 1,
                   "the killed party never rejoined");
        lanes.shutdown();
        let label_fault: Vec<(u16, (u64, u64, u64))> = lanes
            .link_stats()
            .iter()
            .map(|(p, s)| (p.0, triple(*s)))
            .collect();
        let events = lanes.take_events();
        assert!(events.iter().any(|e| matches!(
            e, SessionEvent::PeerRejoined { party: PartyId(1), .. })),
            "no rejoin event: {events:?}");
        let (resume, p1_post) = h1.join().unwrap().unwrap();
        let p2_fault = h2.join().unwrap().unwrap();

        // ---- parity vs the reference ----------------------------------------
        // Round-count parity is structural: both label loops above ran
        // exactly N rounds and every feature loop asserted lock-step
        // round numbers. The surviving P2 link is byte-identical in
        // both directions.
        let ref_p1 = feature_ref[0];
        assert_eq!(p2_fault, feature_ref[1],
                   "surviving feature link diverged");
        let at = |v: &[(u16, (u64, u64, u64))], p: u16| {
            v.iter().find(|(q, _)| *q == p).unwrap().1
        };
        assert_eq!(at(&label_fault, 2), at(&label_ref, 2),
                   "label→P2 link diverged");
        // The restarted P1 link carries exactly the surviving rounds'
        // bytes. The reference sent N identical activation frames, so
        // its per-round cost divides evenly.
        assert_eq!(ref_p1.2, N, "reference P1 frame count");
        assert_eq!((ref_p1.0 % N, ref_p1.1 % N), (0, 0));
        let survived = N - resume;
        assert_eq!(
            p1_post,
            (ref_p1.0 / N * survived, ref_p1.1 / N * survived,
             survived),
            "post-resume P1 link not byte-identical per round \
             (resumed at {resume})"
        );
        // Sanity: fp16 stayed pinned across the snapshot resume.
        assert!(p1_post.0 < p1_post.1,
                "fp16 lane not compressed after resume: {p1_post:?}");
        let _ = std::fs::remove_dir_all(&dir);
    }

    /// A reordered activation (nth/nth+1 swapped on the wire) must not
    /// panic or wedge the lifecycle: the held round stales exactly one
    /// straggler window, the out-of-order arrival drains as a
    /// catch-up, and the session returns to `Running` with zero
    /// garbled-frame discards.
    #[test]
    fn reorder_injection_stales_one_round_then_catches_up() {
        const ROUNDS: u64 = 4;
        let mut cfg = RunConfig::quick();
        cfg.parties = 3;
        cfg.wan = crate::config::WanProfile::instant();
        cfg.straggler_wait_ms = 400;
        cfg.compress = CodecKind::Identity;
        cfg.validate().unwrap();
        let (label_bs, feature_bs) = inproc_mesh(&cfg);

        // P1's round-1 activation is held and delivered *after* its
        // round-2 activation; P2 is untouched. The feature loop sends
        // exactly one frame per round, so wire index == round.
        let plans = [FaultPlan::new(7).reorder_frames(1),
                     FaultPlan::new(8)];
        let mut features = Vec::new();
        for (bs, plan) in feature_bs.into_iter().zip(plans) {
            features.push(std::thread::spawn({
                let cfg = cfg.clone();
                move || -> anyhow::Result<u64> {
                    let links = bs.establish(&cfg)?;
                    let ft = Arc::new(FaultTransport::new(
                        links[0].transport.clone(), plan));
                    for round in 0..ROUNDS {
                        ft.send(act(round))?;
                        let m = ft.recv()?;
                        anyhow::ensure!(m.round() == round,
                                        "skew at {round}");
                    }
                    loop {
                        match ft.recv() {
                            Ok(Message::Shutdown) | Err(_) => break,
                            Ok(_) => {}
                        }
                    }
                    Ok(ft.injected())
                }
            }));
        }

        let links = label_bs.establish(&cfg).unwrap();
        let mut lanes = LaneSet::new(&cfg, &links, None);
        lanes.handshake(&cfg, None).unwrap();
        let mut freshness = Vec::new();
        for round in 0..ROUNDS {
            let inputs = lanes.collect(round).unwrap();
            freshness.push((inputs[0].is_fresh(),
                            inputs[1].is_fresh()));
            let zs: Vec<Tensor> = inputs
                .iter()
                .filter_map(|i| i.tensor().cloned())
                .collect();
            lanes.fan_out(round, &Tensor::sum_f32(&zs).unwrap())
                 .unwrap();
        }
        assert_eq!(freshness, vec![
            (true, true),  // round 0: clean
            (false, true), // round 1: P1's frame held by the reorder
            (true, true),  // round 2: frames 2 then 1 both arrive
            (true, true),  // round 3: the held frame drained behind 2
        ]);
        assert!(lanes.catch_ups() >= 1,
                "the reordered frame never drained as catch-up");
        assert_eq!(lanes.discards(), 0,
                   "a reordered clean frame must never be discarded");
        assert_eq!(lanes.state(), SessionState::Running);
        lanes.shutdown();
        let injected: Vec<u64> = features
            .into_iter()
            .map(|h| h.join().unwrap().unwrap())
            .collect();
        assert_eq!(injected, vec![1, 0]);
    }

    /// Satellite: the victim's *Rejoin itself* dies mid-handshake (a
    /// vetting socket opens, sends a valid Rejoin frame, and drops the
    /// connection before reading the ack). The session must absorb the
    /// aborted attempt — whether the ack write fails or a dead
    /// transport briefly seats and is lost on the next fan-out — and
    /// the second attempt must succeed with byte-identical surviving
    /// links vs an undisturbed reference run.
    #[test]
    fn kill_during_rejoin_second_attempt_succeeds() {
        const N: u64 = 8;
        const KILL: u64 = 3;

        fn victim_loop(addr: String, cfg: RunConfig)
                       -> anyhow::Result<(u64, (u64, u64, u64))> {
            let party = PartyId(1);
            let links = SessionDialer::new(&addr, party)
                .with_timeout(Duration::from_secs(10))
                .establish(&cfg)?;
            let codec = compress::negotiate(cfg.codec_for(party.0),
                                            links[0].peer_codecs);
            let epoch = session_epoch(cfg.seed);
            let plan = FaultPlan::new(0xDEAD).kill_at_round(KILL);
            let faulted: Arc<dyn Transport> = Arc::new(
                FaultTransport::new(links[0].transport.clone(), plan));
            let mut completed = 0u64;
            loop {
                let za = t(party.0 as f32 + completed as f32);
                let (msg, _) = outbound_stats(codec, Lane::Activation,
                                              completed, za)?;
                if faulted.send(msg).is_err() {
                    break; // the injected kill point
                }
                match faulted.recv()?.into_plain()? {
                    Message::Derivative { round: r, .. } => {
                        anyhow::ensure!(r == completed, "skew: {r}");
                    }
                    other => anyhow::bail!("unexpected {:?}",
                                           other.tag()),
                }
                completed += 1;
            }
            anyhow::ensure!(completed == KILL,
                            "killed at {completed}, planned {KILL}");
            // First rejoin attempt, killed mid-handshake: a valid
            // Rejoin frame goes out, then the socket dies before the
            // RejoinAck is read.
            {
                let mut s = std::net::TcpStream::connect(&addr)?;
                crate::session::bootstrap::send_bootstrap_frame(
                    &mut s,
                    &Message::Rejoin {
                        party,
                        parties: cfg.parties as u16,
                        epoch,
                        last_round: completed,
                        codecs: compress::supported_mask(),
                    })?;
            } // drop: the dialer is gone before the ack arrives
            // Let the aborted contact clear the vetting workers so the
            // two attempts cannot seat out of order.
            std::thread::sleep(Duration::from_millis(150));
            // Second attempt: must go through normally.
            let (fresh, resume, replays) = rejoin_dial(
                &addr, party, &cfg, epoch, completed,
                Duration::from_secs(10))?;
            anyhow::ensure!(resume >= KILL && resume < N,
                            "resumed at {resume}, outside [{KILL}, {N})");
            for _ in 0..replays {
                let _ = fresh.recv()?; // stale in-flight derivatives
            }
            for round in resume..N {
                let za = t(party.0 as f32 + round as f32);
                let (msg, _) = outbound_stats(codec, Lane::Activation,
                                              round, za)?;
                fresh.send(msg)?;
                match fresh.recv()?.into_plain()? {
                    Message::Derivative { round: r, .. } => {
                        anyhow::ensure!(r == round,
                                        "post-resume skew: {r}");
                    }
                    other => anyhow::bail!("unexpected {:?}",
                                           other.tag()),
                }
            }
            loop {
                match fresh.recv() {
                    Ok(Message::Shutdown) | Err(_) => break,
                    Ok(_) => {}
                }
            }
            drop(links);
            Ok((resume, triple(fresh.stats())))
        }

        let mut cfg = RunConfig::quick();
        cfg.parties = 3;
        cfg.wan = crate::config::WanProfile::instant();
        cfg.straggler_wait_ms = 500;
        cfg.compress = CodecKind::Identity;
        cfg.validate().unwrap();

        // ---- reference: undisturbed K = 3 run -------------------------------
        let listener = SessionListener::bind("127.0.0.1:0")
            .unwrap()
            .with_timeout(Duration::from_secs(10));
        let addr = listener.local_addr().unwrap().to_string();
        let features: Vec<_> = [1u16, 2]
            .iter()
            .map(|&p| {
                let addr = addr.clone();
                let cfg = cfg.clone();
                std::thread::spawn(move || {
                    tcp_feature_loop(addr, PartyId(p), cfg, N, 0)
                })
            })
            .collect();
        let (links, readmission, _e, _s) =
            listener.establish_supervised(&cfg).unwrap();
        let mut lanes = LaneSet::new(&cfg, &links, Some(readmission));
        lanes.handshake(&cfg, None).unwrap();
        label_segment(&cfg, &mut lanes, 0, N).unwrap();
        lanes.shutdown();
        let label_ref: Vec<(u16, (u64, u64, u64))> = lanes
            .link_stats()
            .iter()
            .map(|(p, s)| (p.0, triple(*s)))
            .collect();
        let mut feature_ref = Vec::new();
        for h in features {
            feature_ref.push(h.join().unwrap().unwrap());
        }

        // ---- fault run: P1 killed, first rejoin aborted mid-handshake -------
        let listener = SessionListener::bind("127.0.0.1:0")
            .unwrap()
            .with_timeout(Duration::from_secs(10));
        let addr = listener.local_addr().unwrap().to_string();
        let h1 = std::thread::spawn({
            let addr = addr.clone();
            let cfg = cfg.clone();
            move || victim_loop(addr, cfg)
        });
        let h2 = std::thread::spawn({
            let addr = addr.clone();
            let cfg = cfg.clone();
            move || tcp_feature_loop(addr, PartyId(2), cfg, N, 0)
        });
        let (links, readmission, _e, _s) =
            listener.establish_supervised(&cfg).unwrap();
        let mut lanes = LaneSet::new(&cfg, &links, Some(readmission));
        lanes.handshake(&cfg, None).unwrap();
        // No freshness assert: the victim's lane is silent between the
        // kill and its (second) rejoin.
        for round in 0..N {
            let inputs = lanes.collect(round).unwrap();
            let zs: Vec<Tensor> = inputs
                .iter()
                .filter_map(|i| i.tensor().cloned())
                .collect();
            lanes.fan_out(round, &Tensor::sum_f32(&zs).unwrap())
                 .unwrap();
        }
        assert!(lanes.total_rejoins() >= 1,
                "the second rejoin never seated a transport");
        lanes.shutdown();
        let label_fault: Vec<(u16, (u64, u64, u64))> = lanes
            .link_stats()
            .iter()
            .map(|(p, s)| (p.0, triple(*s)))
            .collect();
        let events = lanes.take_events();
        assert!(events.iter().any(|e| matches!(
            e, SessionEvent::PeerRejoined { party: PartyId(1), .. })),
            "no rejoin event: {events:?}");
        let (resume, p1_post) = h1.join().unwrap().unwrap();
        let p2_fault = h2.join().unwrap().unwrap();

        // ---- byte-identity of the surviving links ---------------------------
        let ref_p1 = feature_ref[0];
        assert_eq!(p2_fault, feature_ref[1],
                   "surviving feature link diverged");
        let at = |v: &[(u16, (u64, u64, u64))], p: u16| {
            v.iter().find(|(q, _)| *q == p).unwrap().1
        };
        assert_eq!(at(&label_fault, 2), at(&label_ref, 2),
                   "label→P2 link diverged");
        // The re-admitted P1 link carries exactly the surviving
        // rounds' bytes (the reference's per-round cost divides
        // evenly across its N identical frames).
        assert_eq!(ref_p1.2, N, "reference P1 frame count");
        assert_eq!((ref_p1.0 % N, ref_p1.1 % N), (0, 0));
        let survived = N - resume;
        assert_eq!(
            p1_post,
            (ref_p1.0 / N * survived, ref_p1.1 / N * survived,
             survived),
            "post-rejoin P1 link not byte-identical per round \
             (resumed at {resume})"
        );
    }
}
