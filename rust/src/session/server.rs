//! The multi-session service plane: one process, one port, many
//! meshes (DESIGN.md §11).
//!
//! A [`super::bootstrap::SessionListener`] is a single-tenant server —
//! bind, admit K−1 peers, train, exit. A [`SessionServer`] binds
//! *once* and hosts any number of independent training sessions behind
//! that one socket, multiplexing their bootstraps through the
//! [`super::reactor::Reactor`] and their observability through one
//! labeled `/metrics` exposition. Routing is by **session epoch** —
//! the seed-derived 32-bit id every checkpoint and `Rejoin` frame
//! already carries ([`session_epoch`]) — with zero wire changes:
//!
//! - `Rejoin{epoch}` routes exactly: unknown epoch →
//!   [`Message::RejoinReject`] (`EpochMismatch`); an *assembling*
//!   session admits it as a join (`RejoinAck{resume_round: 0}`); a
//!   *running* session gets it forwarded as a [`RejoinRequest`]
//!   through the [`Readmission::external`] channel its label loop
//!   already polls.
//! - A plain `Join` carries no epoch (those golden bytes are frozen),
//!   so it is seated directly only when the server hosts exactly one
//!   session — the single-tenant contract. With several sessions *any*
//!   plain Join is answered `RejoinReject{NeedRejoin}`, even when only
//!   one mesh is currently assembling: a crashed party of a *running*
//!   session re-dialing fresh would otherwise be mis-seated into
//!   whichever mesh happens to have its id free. The stock dialer's
//!   fallback re-dials with an epoch-bearing `Rejoin` that routes
//!   exactly. Hosting two same-seed sessions is refused at [`host`]
//!   time for the same reason the wire can't express it.
//!
//! The server is training-agnostic: when a mesh completes, it wraps
//! the admitted sockets ([`SessionListener::wrap_links`] — the same
//! code path as single-tenant, so single-session wire behaviour is
//! byte-identical) and hands a [`SessionHandle`] to the caller's
//! runner on a fresh thread. Worksets across sessions can share one
//! [`CacheBudget`], bounding the *process's* cache residency while
//! each session keeps its own W bound.
//!
//! [`host`]: SessionServer::host

use std::collections::BTreeMap;
use std::net::{SocketAddr, TcpStream};
use std::sync::atomic::AtomicBool;
use std::sync::mpsc::Sender;
use std::sync::Arc;
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

use crate::compress;
use crate::config::RunConfig;
use crate::metrics::exporters::prometheus;
use crate::metrics::facade::Registry;
use crate::protocol::{Message, RejectReason};
use crate::workset::CacheBudget;

use super::bootstrap::{send_bootstrap_frame, send_http_response,
                       watch_stream_loop, HttpRequest, Readmission,
                       RejoinRequest, SessionListener, ACCEPT_POLL,
                       DEFAULT_JOIN_TIMEOUT};
use super::reactor::{Reactor, Ready};
use super::supervisor::session_epoch;
use super::Link;

/// Everything a hosted session's runner needs, delivered on the
/// session's own thread once its mesh has assembled. The runner owns
/// the handle: typically `SessionBuilder` + `run_label_with`, wiring
/// `readmission`, `registry` and `cache_budget` straight into
/// [`crate::coordinator::label_party::LabelRunOpts`].
pub struct SessionHandle {
    pub cfg: RunConfig,
    /// The session's routing epoch ([`session_epoch`] of `cfg.seed`).
    pub epoch: u32,
    /// The epoch rendered as the `session="…"` label every `/metrics`
    /// sample of this session carries.
    pub label: String,
    /// One link per admitted feature party, id order — exactly what
    /// `SessionListener::establish` would have produced.
    pub links: Vec<Link>,
    /// The externally-fed re-admission point: the server routes
    /// mid-session `Rejoin`s here; the label loop polls it unchanged.
    pub readmission: Readmission,
    /// This session's private registry; the server scrapes it labeled.
    pub registry: Arc<Registry>,
    /// The process-wide workset budget, when the server has one.
    pub cache_budget: Option<Arc<CacheBudget>>,
}

/// What became of one hosted session.
pub struct SessionOutcome {
    pub label: String,
    pub epoch: u32,
    pub result: anyhow::Result<()>,
}

enum Phase {
    /// Collecting joins: party id → (socket, codec mask).
    Admitting {
        joined: BTreeMap<u16, (TcpStream, u32)>,
        deadline: Instant,
    },
    /// Mesh assembled, runner thread live.
    Running {
        rejoin_tx: Sender<RejoinRequest>,
        stop: Arc<AtomicBool>,
        handle: JoinHandle<anyhow::Result<()>>,
    },
    Done(anyhow::Result<()>),
}

struct Hosted {
    cfg: RunConfig,
    epoch: u32,
    label: String,
    registry: Arc<Registry>,
    phase: Phase,
}

impl Hosted {
    fn feature_parties(&self) -> usize {
        self.cfg.feature_parties()
    }
}

/// The long-lived server: bind once, [`host`](Self::host) any number
/// of session configs, then [`serve`](Self::serve) them all to
/// completion through one reactor loop.
pub struct SessionServer {
    reactor: Reactor,
    sessions: Vec<Hosted>,
    token: Option<String>,
    budget: Option<Arc<CacheBudget>>,
    join_timeout: Duration,
}

impl SessionServer {
    pub fn bind(addr: &str) -> anyhow::Result<Self> {
        let listener = std::net::TcpListener::bind(addr)
            .map_err(|e| anyhow::anyhow!("binding {addr}: {e}"))?;
        Ok(SessionServer {
            reactor: Reactor::new(listener)?,
            sessions: Vec::new(),
            token: None,
            budget: None,
            join_timeout: DEFAULT_JOIN_TIMEOUT,
        })
    }

    pub fn local_addr(&self) -> anyhow::Result<SocketAddr> {
        self.reactor.local_addr()
    }

    /// The shared-token observability gate (same semantics as
    /// [`SessionListener::with_auth_token`]): empty leaves the plane
    /// open; sessions are never gated.
    pub fn with_auth_token(mut self, token: &str) -> Self {
        self.token = (!token.is_empty()).then(|| token.to_string());
        self
    }

    /// Bound the summed workset residency of every hosted session.
    pub fn with_cache_budget(mut self, budget: Arc<CacheBudget>) -> Self {
        self.budget = Some(budget);
        self
    }

    /// Per-session window for the full mesh to assemble (measured from
    /// [`serve`](Self::serve), not from `host`).
    pub fn with_join_timeout(mut self, timeout: Duration) -> Self {
        self.join_timeout = timeout;
        self
    }

    /// Register one session to host. Returns its routing epoch. Two
    /// sessions of the same seed share an epoch and are refused —
    /// `Rejoin` frames could not tell them apart.
    pub fn host(&mut self, cfg: RunConfig) -> anyhow::Result<u32> {
        cfg.validate()?;
        let epoch = session_epoch(cfg.seed);
        anyhow::ensure!(
            !self.sessions.iter().any(|s| s.epoch == epoch),
            "a hosted session already uses seed {} (epoch {epoch:#x}) — \
             sessions on one server need distinct seeds to route by",
            cfg.seed
        );
        let label = format!("{epoch:08x}");
        self.sessions.push(Hosted {
            cfg,
            epoch,
            label,
            registry: Registry::new(),
            phase: Phase::Admitting {
                joined: BTreeMap::new(),
                // Provisional; serve() re-arms so the window measures
                // from when dialers can actually be answered.
                deadline: Instant::now() + self.join_timeout,
            },
        });
        Ok(epoch)
    }

    /// Run every hosted session to completion. `runner` is called once
    /// per session on a dedicated thread the moment its mesh
    /// assembles; the server keeps routing (later sessions' joins,
    /// mid-session rejoins, scrapes) the whole time. Returns one
    /// outcome per session, in [`host`](Self::host) order.
    pub fn serve<R>(mut self, runner: R)
                    -> anyhow::Result<Vec<SessionOutcome>>
    where
        R: Fn(SessionHandle) -> anyhow::Result<()>
            + Send + Sync + 'static,
    {
        anyhow::ensure!(!self.sessions.is_empty(),
                        "serve() with no hosted sessions");
        let runner: Arc<dyn RunnerFn> = Arc::new(runner);
        let start = Instant::now() + self.join_timeout;
        for s in &mut self.sessions {
            if let Phase::Admitting { deadline, .. } = &mut s.phase {
                *deadline = start;
            }
        }
        loop {
            let ready = self.reactor.poll();
            let idle = ready.is_empty();
            for contact in ready {
                match contact {
                    Ready::Frame(msg, stream) => {
                        self.route_frame(msg, stream);
                    }
                    Ready::Http(req, stream) => {
                        self.route_http(&req, stream);
                    }
                }
            }
            self.promote(&runner);
            self.reap();
            if self.sessions.iter()
                .all(|s| matches!(s.phase, Phase::Done(_)))
            {
                break;
            }
            if idle {
                std::thread::sleep(ACCEPT_POLL);
            }
        }
        Ok(self.sessions.drain(..)
            .map(|s| SessionOutcome {
                label: s.label,
                epoch: s.epoch,
                result: match s.phase {
                    Phase::Done(r) => r,
                    _ => unreachable!("serve loop ended mid-phase"),
                },
            })
            .collect())
    }

    /// Route one decoded bootstrap frame to its session — or refuse it
    /// on the wire so the dialer can react (fall back to `Rejoin`,
    /// give up on a wrong epoch) instead of staring at an EOF.
    fn route_frame(&mut self, msg: Message, mut stream: TcpStream) {
        match msg {
            Message::Join { party, parties, codecs } => {
                // No epoch on the wire: seat it directly only in the
                // single-tenant case, where the answer cannot be wrong.
                let sole = match &mut self.sessions[..] {
                    [s] => matches!(&s.phase,
                                    Phase::Admitting { joined, .. }
                                    if s.cfg.parties as u16 == parties
                                    && party.0 >= 1 && party.0 < parties
                                    && !joined.contains_key(&party.0))
                        .then_some(s),
                    _ => None,
                };
                match sole {
                    Some(s) => {
                        let ack = Message::JoinAck {
                            party,
                            parties,
                            codecs: compress::supported_mask(),
                        };
                        admit(s, party.0, codecs, stream, &ack);
                    }
                    None => {
                        log::info!(
                            "server: plain Join from {party} \
                             ({parties}-party) cannot be routed by \
                             content — answering NeedRejoin so the \
                             dialer retries with an epoch"
                        );
                        let _ = send_bootstrap_frame(
                            &mut stream,
                            &Message::RejoinReject {
                                party,
                                reason: RejectReason::NeedRejoin,
                                round: 0,
                            });
                    }
                }
            }
            Message::Rejoin { party, parties, epoch, last_round,
                              codecs } => {
                let Some(s) = self.sessions.iter_mut()
                    .find(|s| s.epoch == epoch)
                else {
                    log::warn!(
                        "server: Rejoin from {party} names epoch \
                         {epoch:#x} — no such session here"
                    );
                    let _ = send_bootstrap_frame(
                        &mut stream,
                        &Message::RejoinReject {
                            party,
                            reason: RejectReason::EpochMismatch,
                            round: 0,
                        });
                    return;
                };
                if parties != s.cfg.parties as u16
                    || party.0 < 1 || party.0 >= parties
                {
                    log::warn!(
                        "server: {party} rejoined session {} claiming \
                         {parties} parties, config says {} — dropped",
                        s.label, s.cfg.parties
                    );
                    return;
                }
                match &mut s.phase {
                    // An epoch-bearing join into an assembling mesh:
                    // the dialer's NeedRejoin fallback lands here.
                    Phase::Admitting { joined, .. } => {
                        if joined.contains_key(&party.0) {
                            log::warn!(
                                "server: duplicate {party} for session \
                                 {} — dropped", s.label
                            );
                            return;
                        }
                        let ack = Message::RejoinAck {
                            party,
                            parties,
                            epoch,
                            resume_round: 0,
                            replays: 0,
                        };
                        admit(s, party.0, codecs, stream, &ack);
                    }
                    Phase::Running { rejoin_tx, .. } => {
                        // Mid-session recovery: the lane consumer acks
                        // and swaps transports, exactly as the
                        // single-tenant re-admission loop feeds it.
                        let _ = rejoin_tx.send(RejoinRequest {
                            party,
                            last_round,
                            codecs,
                            stream,
                        });
                    }
                    Phase::Done(_) => {
                        log::warn!(
                            "server: {party} rejoined session {} which \
                             already ended", s.label
                        );
                        let _ = send_bootstrap_frame(
                            &mut stream,
                            &Message::RejoinReject {
                                party,
                                reason: RejectReason::EpochMismatch,
                                round: 0,
                            });
                    }
                }
            }
            other => log::warn!(
                "server: unexpected bootstrap message tag {} — dropped",
                other.tag()
            ),
        }
    }

    /// The multi-session observability plane: `/metrics` concatenates
    /// every session's exposition with a `session="…"` label, and
    /// `/metrics?session=<label>` narrows it to one hosted session
    /// (404 for an unknown label); `/watch/<label>` streams one
    /// session (bare `/watch` works while exactly one session is
    /// hosted, preserving the single-tenant contract).
    fn route_http(&mut self, req: &HttpRequest, mut stream: TcpStream) {
        if let Some(token) = &self.token {
            let expect = format!("Bearer {token}");
            if req.auth.as_deref() != Some(expect.as_str()) {
                send_http_response(
                    &mut stream, "401 Unauthorized", "text/plain",
                    "observability endpoints require \
                     `Authorization: Bearer <token>`\n");
                return;
            }
        }
        // The query string rides in the request path verbatim; only
        // `/metrics` consumes one today.
        let (path, query) = match req.path.split_once('?') {
            Some((p, q)) => (p, Some(q)),
            None => (req.path.as_str(), None),
        };
        match path {
            "/metrics" => {
                // `?session=<label>` narrows the exposition to one
                // hosted session — what a per-tenant scrape job wants.
                // Bare `/metrics` stays the concatenated default.
                let filter = query.and_then(|q| {
                    q.split('&').find_map(|kv| kv.strip_prefix("session="))
                });
                let selected: Vec<_> = match filter {
                    Some(label) => {
                        let hit: Vec<_> = self.sessions.iter()
                            .filter(|s| s.label == label)
                            .collect();
                        if hit.is_empty() {
                            send_http_response(
                                &mut stream, "404 Not Found", "text/plain",
                                &format!("no session labeled {label}\n"));
                            return;
                        }
                        hit
                    }
                    None => self.sessions.iter().collect(),
                };
                let body: String = selected.iter()
                    .map(|s| prometheus::render_labeled(
                        &s.registry, Some(&s.label)))
                    .collect();
                send_http_response(&mut stream, "200 OK",
                                   "text/plain; version=0.0.4", &body);
            }
            "/watch" if self.sessions.len() == 1 => {
                serve_watch(&self.sessions[0], stream);
            }
            "/watch" => {
                let labels: Vec<&str> = self.sessions.iter()
                    .map(|s| s.label.as_str())
                    .collect();
                send_http_response(
                    &mut stream, "409 Conflict", "text/plain",
                    &format!(
                        "this server hosts {} sessions — pick one: \
                         /watch/{}\n",
                        labels.len(), labels.join(", /watch/")));
            }
            watch if watch.starts_with("/watch/") => {
                let label = &watch["/watch/".len()..];
                match self.sessions.iter()
                    .find(|s| s.label == label)
                {
                    Some(s) => serve_watch(s, stream),
                    None => send_http_response(
                        &mut stream, "404 Not Found", "text/plain",
                        &format!("no session labeled {label}\n")),
                }
            }
            other => send_http_response(
                &mut stream, "404 Not Found", "text/plain",
                &format!(
                    "unknown path {other} — try /metrics, \
                     /metrics?session=<label> or /watch/<session>\n")),
        }
    }

    /// Start every session whose mesh just completed; time out those
    /// whose admit window expired.
    fn promote(&mut self, runner: &Arc<dyn RunnerFn>) {
        for s in &mut self.sessions {
            let Phase::Admitting { joined, deadline } = &mut s.phase
            else {
                continue;
            };
            if joined.len() == s.feature_parties() {
                let joined = std::mem::take(joined);
                s.phase = match launch(s, joined, runner,
                                       self.budget.clone()) {
                    Ok(phase) => phase,
                    Err(e) => Phase::Done(Err(e)),
                };
            } else if Instant::now() >= *deadline {
                let missing: Vec<String> = (1..s.cfg.parties as u16)
                    .filter(|id| !joined.contains_key(id))
                    .map(|id| format!("P{id}"))
                    .collect();
                s.phase = Phase::Done(Err(anyhow::anyhow!(
                    "session {} bootstrap timed out: {} never joined",
                    s.label, missing.join(", ")
                )));
            }
        }
    }

    /// Collect finished runner threads into their outcomes.
    fn reap(&mut self) {
        for s in &mut self.sessions {
            let Phase::Running { handle, .. } = &s.phase else {
                continue;
            };
            if !handle.is_finished() {
                continue;
            }
            let Phase::Running { handle, .. } = std::mem::replace(
                &mut s.phase, Phase::Done(Ok(())))
            else {
                unreachable!();
            };
            let result = handle.join().unwrap_or_else(|_| Err(
                anyhow::anyhow!("session {} runner panicked", s.label)));
            if let Err(e) = &result {
                log::warn!("session {} failed: {e:#}", s.label);
            } else {
                log::info!("session {} completed", s.label);
            }
            s.phase = Phase::Done(result);
        }
    }
}

/// `Fn` alias the promote path can name without repeating the bound.
trait RunnerFn:
    Fn(SessionHandle) -> anyhow::Result<()> + Send + Sync {}
impl<T> RunnerFn for T
    where T: Fn(SessionHandle) -> anyhow::Result<()> + Send + Sync {}

/// Ack-then-seat one admitted socket; a failed ack send costs the
/// joiner, not the session (its dialer retries).
fn admit(s: &mut Hosted, party: u16, codecs: u32, mut stream: TcpStream,
         ack: &Message) {
    let Phase::Admitting { joined, .. } = &mut s.phase else {
        unreachable!("admit outside the admitting phase");
    };
    match send_bootstrap_frame(&mut stream, ack) {
        Ok(()) => {
            log::info!(
                "server: P{party} joined session {} ({}/{} feature \
                 parties)", s.label, joined.len() + 1,
                s.cfg.feature_parties()
            );
            joined.insert(party, (stream, codecs));
        }
        Err(e) => log::warn!(
            "server: acking P{party} into session {} failed: {e:#}",
            s.label
        ),
    }
}

/// Wrap a completed mesh and hand it to the runner on its own thread.
fn launch(s: &Hosted, joined: BTreeMap<u16, (TcpStream, u32)>,
          runner: &Arc<dyn RunnerFn>, budget: Option<Arc<CacheBudget>>)
          -> anyhow::Result<Phase> {
    let links = SessionListener::wrap_links(&s.cfg, joined)?;
    let (rejoin_tx, readmission) = Readmission::external();
    let stop = readmission.stop_flag();
    let handle = SessionHandle {
        cfg: s.cfg.clone(),
        epoch: s.epoch,
        label: s.label.clone(),
        links,
        readmission,
        registry: s.registry.clone(),
        cache_budget: budget,
    };
    let runner = runner.clone();
    let thread = std::thread::Builder::new()
        .name(format!("session-{}", s.label))
        .spawn(move || runner(handle))?;
    log::info!("server: session {} mesh assembled — training started",
               s.label);
    Ok(Phase::Running { rejoin_tx, stop, handle: thread })
}

/// Stream one session's metric frames (the single-tenant `/watch`
/// contract, addressed by label).
fn serve_watch(s: &Hosted, mut stream: TcpStream) {
    match &s.phase {
        Phase::Running { stop, .. } => {
            let registry = s.registry.clone();
            let stop = stop.clone();
            let _ = std::thread::Builder::new()
                .name(format!("watch-{}", s.label))
                .spawn(move || watch_stream_loop(stream, registry, stop));
        }
        Phase::Admitting { .. } => send_http_response(
            &mut stream, "503 Service Unavailable", "text/plain",
            "session still assembling — /watch is served once training \
             starts\n"),
        Phase::Done(_) => send_http_response(
            &mut stream, "410 Gone", "text/plain",
            "session already ended — scrape /metrics for final totals\n"),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::io::{Read as _, Write as _};

    use crate::protocol::decode_frame;
    use crate::session::bootstrap::{recv_bootstrap_frame, SessionDialer};
    use crate::session::PartyId;

    fn cfg_k(parties: usize, seed: u64) -> RunConfig {
        let mut cfg = RunConfig::quick();
        cfg.parties = parties;
        cfg.seed = seed;
        cfg
    }

    /// A runner that records which sessions ran and exchanges one
    /// frame per link so transports see real traffic (`EvalAck{7}` out,
    /// `EvalAck{8}` back — fixed-size control frames).
    fn echo_runner() -> (Arc<std::sync::Mutex<Vec<String>>>,
                         impl Fn(SessionHandle) -> anyhow::Result<()>
                             + Send + Sync + 'static) {
        let ran = Arc::new(std::sync::Mutex::new(Vec::<String>::new()));
        let seen = ran.clone();
        let runner = move |h: SessionHandle| -> anyhow::Result<()> {
            // Publish one sample before any traffic, so a scrape taken
            // after the first frame is guaranteed to see it labeled.
            h.registry.gauge("celu_echo_sessions").set(1.0);
            for link in &h.links {
                link.transport.send(Message::EvalAck { round: 7 })?;
                let m = link.transport.recv()?;
                anyhow::ensure!(
                    matches!(m, Message::EvalAck { round: 8 }),
                    "expected EvalAck{{8}}, got {m:?}"
                );
            }
            seen.lock().unwrap().push(h.label.clone());
            Ok(())
        };
        (ran, runner)
    }

    /// Dial one feature party of `cfg` and answer the echo runner.
    fn echo_dialer(addr: String, cfg: RunConfig, party: u16)
                   -> std::thread::JoinHandle<anyhow::Result<()>> {
        std::thread::spawn(move || {
            let (link, start) = SessionDialer::new(&addr, PartyId(party))
                .with_timeout(Duration::from_secs(10))
                .establish_resumable(&cfg)?;
            anyhow::ensure!(start == 0, "fresh dial resumed at {start}");
            let m = link.transport.recv()?;
            anyhow::ensure!(
                matches!(m, Message::EvalAck { round: 7 }),
                "expected EvalAck{{7}}, got {m:?}"
            );
            link.transport.send(Message::EvalAck { round: 8 })?;
            Ok(())
        })
    }

    fn http_get(addr: &str, path: &str, header: &str) -> String {
        let mut s = TcpStream::connect(addr).unwrap();
        let extra = if header.is_empty() {
            String::new()
        } else {
            format!("{header}\r\n")
        };
        s.write_all(
            format!("GET {path} HTTP/1.0\r\n{extra}\r\n").as_bytes())
            .unwrap();
        let mut out = String::new();
        s.read_to_string(&mut out).unwrap();
        out
    }

    #[test]
    fn two_sessions_complete_through_one_server() {
        let mut server = SessionServer::bind("127.0.0.1:0").unwrap()
            .with_join_timeout(Duration::from_secs(10));
        let cfg_a = cfg_k(3, 11);
        let cfg_b = cfg_k(3, 22);
        let ea = server.host(cfg_a.clone()).unwrap();
        let eb = server.host(cfg_b.clone()).unwrap();
        assert_ne!(ea, eb);
        let addr = server.local_addr().unwrap().to_string();
        // Same party ids, same K, concurrently: plain Joins are
        // ambiguous by construction, so every dial exercises the
        // NeedRejoin → epoch-bearing-Rejoin fallback.
        let dialers: Vec<_> = [(&cfg_a, 1), (&cfg_a, 2),
                               (&cfg_b, 1), (&cfg_b, 2)]
            .into_iter()
            .map(|(cfg, p)| echo_dialer(addr.clone(), cfg.clone(), p))
            .collect();
        let (ran, runner) = echo_runner();
        let outcomes = server.serve(runner).unwrap();
        for d in dialers {
            d.join().unwrap().unwrap();
        }
        assert_eq!(outcomes.len(), 2);
        for o in &outcomes {
            assert!(o.result.is_ok(),
                    "session {} failed: {:?}", o.label, o.result);
        }
        let mut seen = ran.lock().unwrap().clone();
        seen.sort();
        let mut want = vec![format!("{ea:08x}"), format!("{eb:08x}")];
        want.sort();
        assert_eq!(seen, want);
    }

    #[test]
    fn single_session_join_is_unambiguous_and_scrapes_labeled() {
        let mut server = SessionServer::bind("127.0.0.1:0").unwrap()
            .with_join_timeout(Duration::from_secs(10));
        let cfg = cfg_k(3, 5);
        let epoch = server.host(cfg.clone()).unwrap();
        let addr = server.local_addr().unwrap().to_string();
        // With one assembling session a *plain* Join must route — the
        // single-tenant contract. Drive the raw frames so the test
        // fails if the server silently relied on the Rejoin fallback.
        let raw = |party: u16| {
            let addr = addr.clone();
            std::thread::spawn(move || {
                let mut s = TcpStream::connect(&addr).unwrap();
                send_bootstrap_frame(&mut s, &Message::Join {
                    party: PartyId(party),
                    parties: 3,
                    codecs: compress::supported_mask(),
                }).unwrap();
                let deadline = Instant::now() + Duration::from_secs(10);
                let ack = recv_bootstrap_frame(&mut s, deadline).unwrap();
                assert!(matches!(ack, Message::JoinAck { .. }),
                        "expected JoinAck, got tag {}", ack.tag());
                // Answer the echo runner on the raw socket: v2 framed
                // (parties > 2), which decode_frame understands.
                let mut scrape = None;
                let mut head = [0u8; 4];
                s.read_exact(&mut head).unwrap();
                let len = u32::from_le_bytes(head) as usize;
                let mut body = vec![0u8; len];
                s.read_exact(&mut body).unwrap();
                let (hdr, m) = decode_frame(&body).unwrap();
                assert!(hdr.is_some(), "training frames are v2 here");
                assert!(matches!(m, Message::EvalAck { round: 7 }),
                        "expected EvalAck{{7}}, got {m:?}");
                // While the session runs, the plane serves both
                // endpoints; scrape from party 1 only — the bare
                // concatenated form, the per-session filter, and an
                // unknown label.
                if party == 1 {
                    scrape = Some((
                        http_get(&addr, "/metrics", ""),
                        http_get(&addr,
                                 &format!("/metrics?session={epoch:08x}"),
                                 ""),
                        http_get(&addr, "/metrics?session=nope", ""),
                    ));
                }
                let body = crate::protocol::encode_frame(
                    Some(crate::protocol::FrameHeader {
                        src: PartyId(party),
                        dst: crate::session::LABEL_PARTY,
                    }),
                    &Message::EvalAck { round: 8 });
                s.write_all(&(body.len() as u32).to_le_bytes()).unwrap();
                s.write_all(&body).unwrap();
                s.flush().unwrap();
                scrape
            })
        };
        let d1 = raw(1);
        let d2 = raw(2);
        let (_ran, runner) = echo_runner();
        let outcomes = server.serve(runner).unwrap();
        let (scrape, filtered, missing) =
            d1.join().unwrap().expect("party 1 scrapes");
        d2.join().unwrap();
        assert!(outcomes[0].result.is_ok());
        let label = format!("session=\"{epoch:08x}\"");
        assert!(scrape.contains(&label),
                "scrape not labeled with {label}:\n{scrape}");
        // `?session=` narrows to the named session (here: the same
        // exposition, since only one is hosted) …
        assert!(filtered.contains("200 OK") && filtered.contains(&label),
                "filtered scrape missing {label}:\n{filtered}");
        // … and an unknown label is a 404 naming the problem, not an
        // empty 200 a dashboard would silently graph as zeros.
        assert!(missing.contains("404") && missing.contains("nope"),
                "unknown session label not refused:\n{missing}");
    }

    #[test]
    fn wrong_epoch_rejoin_is_refused_by_name() {
        let mut server = SessionServer::bind("127.0.0.1:0").unwrap()
            .with_join_timeout(Duration::from_secs(10));
        let cfg = cfg_k(3, 5);
        server.host(cfg.clone()).unwrap();
        let addr = server.local_addr().unwrap().to_string();
        let probe = {
            let addr = addr.clone();
            std::thread::spawn(move || {
                let mut s = TcpStream::connect(&addr).unwrap();
                send_bootstrap_frame(&mut s, &Message::Rejoin {
                    party: PartyId(1),
                    parties: 3,
                    epoch: 0xDEAD_BEEF,
                    last_round: 0,
                    codecs: 0,
                }).unwrap();
                recv_bootstrap_frame(
                    &mut s, Instant::now() + Duration::from_secs(10))
            })
        };
        // Keep the server alive long enough to answer, then satisfy it.
        let d1 = echo_dialer(addr.clone(), cfg.clone(), 1);
        let d2 = echo_dialer(addr.clone(), cfg.clone(), 2);
        let (_ran, runner) = echo_runner();
        server.serve(runner).unwrap();
        d1.join().unwrap().unwrap();
        d2.join().unwrap().unwrap();
        let reject = probe.join().unwrap().unwrap();
        assert!(
            matches!(reject, Message::RejoinReject {
                reason: RejectReason::EpochMismatch, .. }),
            "expected EpochMismatch, got tag {}", reject.tag()
        );
    }

    #[test]
    fn mid_admit_disconnect_does_not_wedge_the_server() {
        let mut server = SessionServer::bind("127.0.0.1:0").unwrap()
            .with_join_timeout(Duration::from_secs(10));
        let cfg = cfg_k(3, 5);
        server.host(cfg.clone()).unwrap();
        let addr = server.local_addr().unwrap().to_string();
        // Half a length word, then gone.
        let mut ghost = TcpStream::connect(&addr).unwrap();
        ghost.write_all(&[12, 0]).unwrap();
        drop(ghost);
        let d1 = echo_dialer(addr.clone(), cfg.clone(), 1);
        let d2 = echo_dialer(addr.clone(), cfg.clone(), 2);
        let (_ran, runner) = echo_runner();
        let outcomes = server.serve(runner).unwrap();
        d1.join().unwrap().unwrap();
        d2.join().unwrap().unwrap();
        assert!(outcomes[0].result.is_ok());
    }

    #[test]
    fn hosting_duplicate_seeds_is_refused() {
        let mut server = SessionServer::bind("127.0.0.1:0").unwrap();
        server.host(cfg_k(3, 9)).unwrap();
        let err = server.host(cfg_k(4, 9)).unwrap_err();
        assert!(err.to_string().contains("distinct seeds"), "{err:#}");
    }
}
