//! K-party training sessions: role-based parties over a transport mesh.
//!
//! The paper's Algorithm 1/2 is written for one feature party and one
//! label party, but nothing it relies on is two-party-specific: the
//! workset cache is per *link*, the comm/compute overlap is per *link*,
//! and the label party's top model consumes the **sum** Σ_k Z_k of the
//! feature parties' activations (the standard K-party topology of
//! C-VFL, Castiglia et al. 2022). This module is the public face of
//! that generalization:
//!
//! - [`PartyId`] / [`PartyRole`] — party identity. Id 0 is always the
//!   label party; ids 1..K are feature parties.
//! - [`Mesh`] — one [`Transport`] per peer with per-peer [`LinkStats`].
//!   A feature party's mesh has exactly one link (to the label party);
//!   the label party's mesh has one link per feature party (a star —
//!   feature parties never talk to each other, so no statistics can
//!   leak sideways).
//! - [`SessionBuilder`] / [`Session`] — ties a role, a config (codec,
//!   workset policy, per-party overrides) and a mesh together and runs
//!   the party to completion.
//! - [`bootstrap`] — how meshes come into existence: the
//!   [`bootstrap::MeshBootstrap`] trait unifies the pre-wired in-proc
//!   star ([`bootstrap::inproc_mesh`]) with the TCP session server
//!   ([`bootstrap::SessionListener`] accepting `Join`-identified
//!   connections, [`bootstrap::SessionDialer`] joining with backoff),
//!   so [`SessionBuilder::from_bootstrap`] yields the same `Session`
//!   regardless of transport.
//! - [`server`] — the multi-session service plane (DESIGN.md §11): a
//!   [`server::SessionServer`] binds once and hosts many independent
//!   sessions in one process, routing bootstraps and rejoins by
//!   session epoch through a nonblocking reactor and serving every
//!   session's metrics from one labeled exposition.
//! - [`supervisor`] — the supervised session lifecycle (DESIGN.md §8):
//!   a validated state machine with typed [`supervisor::SessionEvent`]s,
//!   bounded straggler lanes, and mid-session `Rejoin` re-admission.
//! - [`checkpoint`] — versioned binary label-party snapshots
//!   ([`checkpoint::SessionSnapshot`]) behind `--checkpoint-dir` /
//!   `--resume`.
//!
//! With `parties = 2` the session runs the exact two-party protocol of
//! the earlier PRs: v1 frames (no party-id header), identical message
//! sequence, byte-identical wire traffic — the golden-bytes fixtures in
//! `protocol` pin this. With `parties > 2` every link speaks v2 frames
//! (a 6-byte versioned header carrying source/dest [`PartyId`]) and the
//! `Hello` codec handshake is negotiated independently per link.

pub mod bootstrap;
pub mod checkpoint;
pub(crate) mod reactor;
pub mod server;
pub mod supervisor;

use std::sync::Arc;

use crate::config::RunConfig;
use crate::coordinator::feature_party::{run_feature_party,
                                        FeaturePartyReport,
                                        FeatureRunOpts};
use crate::coordinator::label_party::{run_label_party, LabelPartyReport,
                                      LabelRunOpts};
use crate::data::{PartyAData, PartyBData};
use crate::dataset::{FeatureFeed, LabelFeed};
use crate::metrics::facade::Registry;
use crate::runtime::ArtifactSet;
use crate::transport::{inproc_link, LinkStats, Transport};

/// Hard upper bound on session size: protocol decoding rejects any
/// frame whose source/dest id is ≥ this *before* touching the payload
/// (the same hostile-header discipline as the shape checks), so a
/// corrupt header cannot smuggle an absurd party id into the stack.
pub const MAX_PARTIES: u16 = 64;

/// Identity of one party in a session. Id 0 is the label party by
/// convention; feature parties are 1..K.
#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord, Hash)]
pub struct PartyId(pub u16);

/// The label party's well-known id.
pub const LABEL_PARTY: PartyId = PartyId(0);

impl PartyId {
    /// Role implied by the id: 0 is the label party, everyone else
    /// holds features only.
    pub fn role(self) -> PartyRole {
        if self == LABEL_PARTY {
            PartyRole::Label
        } else {
            PartyRole::Feature
        }
    }
}

impl std::fmt::Display for PartyId {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "P{}", self.0)
    }
}

/// What a party contributes to training.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum PartyRole {
    /// Holds a vertical feature slice and a bottom model; sends Z_k,
    /// receives ∇Z.
    Feature,
    /// Holds features + labels, the bottom and top models, and the
    /// run's control plane (loss, AUC, stopping).
    Label,
}

/// One peer link: who is on the other end and how to reach them.
#[derive(Clone)]
pub struct Link {
    pub peer: PartyId,
    pub transport: Arc<dyn Transport>,
    /// The peer's decodable codec families, when the bootstrap
    /// handshake carried them (`Join`/`JoinAck` bitmask — DESIGN.md
    /// §7). `Some` lets both coordinators pre-negotiate the wire codec
    /// at join time and skip the first-round `Hello` exchange; `None`
    /// (raw transports, pre-session peers) keeps the historic in-band
    /// handshake, byte-identical to the earlier wire.
    pub peer_codecs: Option<u32>,
}

impl Link {
    /// A link with no join-time codec knowledge (the compat default:
    /// codec negotiation happens in-band via `Hello`).
    pub fn new(peer: PartyId, transport: Arc<dyn Transport>) -> Self {
        Link { peer, transport, peer_codecs: None }
    }

    /// Attach the peer's codec-capability bitmask learned at join time.
    pub fn with_peer_codecs(mut self, mask: u32) -> Self {
        self.peer_codecs = Some(mask);
        self
    }
}

/// The party's view of the session topology: one transport per peer,
/// each with its own byte/busy accounting.
pub struct Mesh {
    links: Vec<Link>,
}

impl Mesh {
    pub fn new(links: Vec<Link>) -> Self {
        Mesh { links }
    }

    pub fn links(&self) -> &[Link] {
        &self.links
    }

    pub fn peers(&self) -> impl Iterator<Item = PartyId> + '_ {
        self.links.iter().map(|l| l.peer)
    }

    pub fn len(&self) -> usize {
        self.links.len()
    }

    pub fn is_empty(&self) -> bool {
        self.links.is_empty()
    }

    /// The transport for `peer`, if linked.
    pub fn transport(&self, peer: PartyId) -> Option<&Arc<dyn Transport>> {
        self.links
            .iter()
            .find(|l| l.peer == peer)
            .map(|l| &l.transport)
    }

    /// Per-peer sender-side traffic stats.
    pub fn link_stats(&self) -> Vec<(PartyId, LinkStats)> {
        self.links
            .iter()
            .map(|l| (l.peer, l.transport.stats()))
            .collect()
    }

    /// All links' stats summed (bytes, messages, busy time).
    pub fn total_stats(&self) -> LinkStats {
        let mut total = LinkStats::default();
        for l in &self.links {
            let s = l.transport.stats();
            total.messages += s.messages;
            total.bytes += s.bytes;
            total.raw_bytes += s.raw_bytes;
            total.busy += s.busy;
        }
        total
    }
}

/// Builder for a [`Session`]: identity + config + one link per peer.
pub struct SessionBuilder {
    cfg: RunConfig,
    id: PartyId,
    links: Vec<Link>,
    registry: Option<Arc<Registry>>,
}

impl SessionBuilder {
    /// Start a session description for party `id` under `cfg`. The
    /// config supplies the session-wide knobs (algorithm, W/R/ξ, codec
    /// with per-party overrides, WAN profile, `parties`).
    pub fn new(cfg: &RunConfig, id: PartyId) -> Self {
        SessionBuilder { cfg: cfg.clone(), id, links: Vec::new(),
                         registry: None }
    }

    /// Build a session whose links come from a [`bootstrap`]
    /// implementation: blocks until the mesh exists (trivially for the
    /// in-proc star; until every peer has joined for the TCP session
    /// server), then runs the usual topology validation. The returned
    /// `Session` is indistinguishable from one wired link-by-link —
    /// transports are the only thing a bootstrap decides.
    pub fn from_bootstrap(
        cfg: &RunConfig,
        bootstrap: impl bootstrap::MeshBootstrap,
    ) -> anyhow::Result<Session> {
        Self::bootstrap_builder(cfg, bootstrap)?.build()
    }

    /// [`Self::from_bootstrap`] stopped one step short of `build`, so
    /// callers can attach builder options (e.g. a shared
    /// [`Registry`] via [`Self::with_registry`]) before the topology
    /// check runs.
    pub fn bootstrap_builder(
        cfg: &RunConfig,
        bootstrap: impl bootstrap::MeshBootstrap,
    ) -> anyhow::Result<SessionBuilder> {
        let id = bootstrap.id();
        let mut b = SessionBuilder::new(cfg, id);
        for l in bootstrap.establish(cfg)? {
            b = b.link_full(l);
        }
        Ok(b)
    }

    /// Publish this session's links into `registry` instead of a
    /// private one — the in-proc trainer hands every party the same
    /// registry so one scrape covers all 2(K−1) directed links.
    pub fn with_registry(mut self, registry: Arc<Registry>) -> Self {
        self.registry = Some(registry);
        self
    }

    /// Add a peer link. Feature parties link exactly the label party;
    /// the label party links every feature party.
    pub fn link(self, peer: PartyId,
                transport: Arc<dyn Transport>) -> Self {
        self.link_full(Link::new(peer, transport))
    }

    /// Add a fully-described peer link (keeps join-time codec masks and
    /// any future link metadata intact — `link` is the mask-less
    /// shorthand).
    pub fn link_full(mut self, link: Link) -> Self {
        self.links.push(link);
        self
    }

    /// Validate the topology and produce a runnable [`Session`]. Every
    /// link whose transport exposes metrics handles is bound into the
    /// session registry as the directed row `(id → peer)` — the
    /// observability plane sees the mesh the moment it exists, before
    /// the first training frame.
    pub fn build(self) -> anyhow::Result<Session> {
        let SessionBuilder { cfg, id, links, registry } = self;
        cfg.validate()?;
        let k = cfg.parties as u16;
        anyhow::ensure!(id.0 < k,
                        "party id {id} out of range for {k} parties");
        for l in &links {
            anyhow::ensure!(l.peer.0 < k,
                            "peer id {} out of range for {k} parties",
                            l.peer);
            anyhow::ensure!(l.peer != id, "party {id} linked to itself");
        }
        let mut peers: Vec<u16> = links.iter().map(|l| l.peer.0).collect();
        peers.sort_unstable();
        peers.dedup();
        anyhow::ensure!(peers.len() == links.len(),
                        "duplicate peer link in session for {id}");
        match id.role() {
            PartyRole::Feature => {
                anyhow::ensure!(
                    links.len() == 1 && links[0].peer == LABEL_PARTY,
                    "feature party {id} must link exactly the label \
                     party ({LABEL_PARTY})"
                );
            }
            PartyRole::Label => {
                anyhow::ensure!(
                    links.len() == cfg.feature_parties(),
                    "label party must link every feature party: got {} \
                     links for {} feature parties",
                    links.len(),
                    cfg.feature_parties()
                );
                anyhow::ensure!(
                    links.iter().all(|l| l.peer.role()
                                     == PartyRole::Feature),
                    "label party may only link feature parties"
                );
            }
        }
        let registry = registry.unwrap_or_else(Registry::new);
        for l in &links {
            if let Some(h) = l.transport.metrics() {
                registry.bind_link(id, l.peer, &h);
            }
        }
        Ok(Session { cfg, id, mesh: Mesh::new(links), registry })
    }
}

/// A fully-wired party, ready to train. The two-party entry points
/// (`coordinator::run_party_a` / `run_party_b`, the `train` and `party`
/// CLI subcommands) are thin wrappers that build one of these with
/// `parties = 2`.
pub struct Session {
    cfg: RunConfig,
    id: PartyId,
    mesh: Mesh,
    registry: Arc<Registry>,
}

impl Session {
    pub fn id(&self) -> PartyId {
        self.id
    }

    pub fn role(&self) -> PartyRole {
        self.id.role()
    }

    pub fn mesh(&self) -> &Mesh {
        &self.mesh
    }

    pub fn config(&self) -> &RunConfig {
        &self.cfg
    }

    /// This session's metrics registry (private unless the builder was
    /// given a shared one). Its link rows alias the mesh transports'
    /// live counters — a scrape here never touches the hot path.
    pub fn registry(&self) -> &Arc<Registry> {
        &self.registry
    }

    /// Run this session as a feature party (role must match).
    pub fn run_feature(&self, set: Arc<ArtifactSet>, train: Arc<PartyAData>,
                       test: Arc<PartyAData>)
                       -> anyhow::Result<FeaturePartyReport> {
        self.run_feature_with(set, train, test,
                              FeatureRunOpts::default())
    }

    /// [`Self::run_feature`] with supervised-lifecycle options (rejoin
    /// reconnect policy — DESIGN.md §8). Wraps `train` in an in-memory
    /// [`FeatureFeed`], which replays the historic batch-cursor
    /// sequence verbatim — the wire stays byte-identical.
    pub fn run_feature_with(&self, set: Arc<ArtifactSet>,
                            train: Arc<PartyAData>, test: Arc<PartyAData>,
                            opts: FeatureRunOpts)
                            -> anyhow::Result<FeaturePartyReport> {
        let feed =
            FeatureFeed::in_memory(train, self.cfg.seed,
                                   set.manifest.batch);
        self.run_feature_data(set, feed, test, opts)
    }

    /// Run this session as a feature party over an explicit data-plane
    /// feed (DESIGN.md §12): streaming CSV/libsvm windows, or an
    /// in-memory table carrying an unaligned-row SSL reservoir.
    pub fn run_feature_data(&self, set: Arc<ArtifactSet>,
                            feed: FeatureFeed, test: Arc<PartyAData>,
                            mut opts: FeatureRunOpts)
                            -> anyhow::Result<FeaturePartyReport> {
        anyhow::ensure!(self.role() == PartyRole::Feature,
                        "run_feature on {} (label party)", self.id);
        if opts.registry.is_none() {
            opts.registry = Some(self.registry.clone());
        }
        run_feature_party(&self.cfg, self.id, set, feed, test,
                          &self.mesh.links[0], opts)
    }

    /// Run this session as the label party (role must match).
    pub fn run_label(&self, set: Arc<ArtifactSet>, train: Arc<PartyBData>,
                     test: Arc<PartyBData>)
                     -> anyhow::Result<LabelPartyReport> {
        self.run_label_with(set, train, test, LabelRunOpts::default())
    }

    /// [`Self::run_label`] with supervised-lifecycle options (the
    /// re-admission point, checkpoint resume — DESIGN.md §8). Wraps
    /// `train` in an in-memory [`LabelFeed`] (historic sequence,
    /// byte-identical wire).
    pub fn run_label_with(&self, set: Arc<ArtifactSet>,
                          train: Arc<PartyBData>, test: Arc<PartyBData>,
                          opts: LabelRunOpts)
                          -> anyhow::Result<LabelPartyReport> {
        let feed =
            LabelFeed::in_memory(train, self.cfg.seed,
                                 set.manifest.batch);
        self.run_label_data(set, feed, test, opts)
    }

    /// Run this session as the label party over an explicit data-plane
    /// feed (DESIGN.md §12).
    pub fn run_label_data(&self, set: Arc<ArtifactSet>, feed: LabelFeed,
                          test: Arc<PartyBData>, mut opts: LabelRunOpts)
                          -> anyhow::Result<LabelPartyReport> {
        anyhow::ensure!(self.role() == PartyRole::Label,
                        "run_label on {} (feature party)", self.id);
        if opts.registry.is_none() {
            opts.registry = Some(self.registry.clone());
        }
        run_label_party(&self.cfg, set, feed, test, self.mesh.links(),
                        opts)
    }
}

/// Build the in-process star topology for `cfg.parties` parties: one
/// duplex link per feature party, all terminating at the label party.
/// Returns the label party's links plus, for each feature party in id
/// order (1..K), its single link back to the label party.
///
/// With `parties == 2` the links carry v1 frames — byte-identical to
/// the two-party path; with more parties every link frames v2 with its
/// endpoints' ids.
pub fn inproc_star(cfg: &RunConfig) -> (Vec<Link>, Vec<Link>) {
    let v2 = cfg.parties > 2;
    let mut label_links = Vec::with_capacity(cfg.feature_parties());
    let mut feature_links = Vec::with_capacity(cfg.feature_parties());
    for f in 1..cfg.parties as u16 {
        let feature = PartyId(f);
        let (to_label, to_feature) =
            inproc_link(cfg.wan, feature, LABEL_PARTY, v2);
        feature_links.push(Link::new(LABEL_PARTY, Arc::new(to_label)));
        label_links.push(Link::new(feature, Arc::new(to_feature)));
    }
    (label_links, feature_links)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::WanProfile;
    use crate::protocol::Message;

    fn cfg_with_parties(k: usize) -> RunConfig {
        let mut cfg = RunConfig::quick();
        cfg.parties = k;
        cfg
    }

    #[test]
    fn party_roles_follow_ids() {
        assert_eq!(PartyId(0).role(), PartyRole::Label);
        assert_eq!(PartyId(1).role(), PartyRole::Feature);
        assert_eq!(PartyId(63).role(), PartyRole::Feature);
        assert_eq!(format!("{}", PartyId(3)), "P3");
        assert_eq!(LABEL_PARTY, PartyId(0));
    }

    #[test]
    fn builder_validates_topology() {
        let cfg = cfg_with_parties(3);
        let (label_links, feature_links) = inproc_star(&cfg);
        // Correct label session: links P1 and P2.
        let mut b = SessionBuilder::new(&cfg, LABEL_PARTY);
        for l in &label_links {
            b = b.link(l.peer, l.transport.clone());
        }
        let s = b.build().unwrap();
        assert_eq!(s.role(), PartyRole::Label);
        assert_eq!(s.mesh().len(), 2);
        // Correct feature session: single link to P0.
        let s = SessionBuilder::new(&cfg, PartyId(1))
            .link(LABEL_PARTY, feature_links[0].transport.clone())
            .build()
            .unwrap();
        assert_eq!(s.role(), PartyRole::Feature);

        // Label party with a missing link is rejected.
        assert!(SessionBuilder::new(&cfg, LABEL_PARTY)
            .link(label_links[0].peer, label_links[0].transport.clone())
            .build()
            .is_err());
        // Feature party linking another feature party is rejected.
        assert!(SessionBuilder::new(&cfg, PartyId(1))
            .link(PartyId(2), feature_links[0].transport.clone())
            .build()
            .is_err());
        // Out-of-range ids are rejected.
        assert!(SessionBuilder::new(&cfg, PartyId(9))
            .link(LABEL_PARTY, feature_links[0].transport.clone())
            .build()
            .is_err());
        // Self-links are rejected.
        assert!(SessionBuilder::new(&cfg, PartyId(1))
            .link(PartyId(1), feature_links[0].transport.clone())
            .build()
            .is_err());
    }

    #[test]
    fn inproc_star_connects_every_feature_party() {
        let cfg = cfg_with_parties(4);
        let (label_links, feature_links) = inproc_star(&cfg);
        assert_eq!(label_links.len(), 3);
        assert_eq!(feature_links.len(), 3);
        // Each feature link reaches the matching label link.
        for (i, fl) in feature_links.iter().enumerate() {
            fl.transport
                .send(Message::EvalAck { round: i as u64 })
                .unwrap();
        }
        for (i, ll) in label_links.iter().enumerate() {
            assert_eq!(ll.peer, PartyId(i as u16 + 1));
            assert_eq!(ll.transport.recv().unwrap().round(), i as u64);
        }
    }

    #[test]
    fn mesh_accumulates_per_link_and_total_stats() {
        let mut cfg = cfg_with_parties(3);
        cfg.wan = WanProfile::instant();
        let (label_links, feature_links) = inproc_star(&cfg);
        let mesh = Mesh::new(label_links);
        let m = Message::EvalAck { round: 1 };
        mesh.transport(PartyId(1)).unwrap().send(m.clone()).unwrap();
        mesh.transport(PartyId(1)).unwrap().send(m.clone()).unwrap();
        mesh.transport(PartyId(2)).unwrap().send(m.clone()).unwrap();
        let stats = mesh.link_stats();
        assert_eq!(stats[0].1.messages, 2);
        assert_eq!(stats[1].1.messages, 1);
        assert_eq!(mesh.total_stats().messages, 3);
        assert!(mesh.total_stats().bytes
                >= stats[0].1.bytes + stats[1].1.bytes);
        // Drain so the feature endpoints don't see dropped senders.
        for fl in &feature_links {
            let _ = fl.transport.try_recv();
        }
        assert!(mesh.transport(PartyId(9)).is_none());
    }
}
