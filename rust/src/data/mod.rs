//! Synthetic vertically-partitioned click datasets + the aligned batcher.
//!
//! The paper evaluates on Criteo, Avazu and a Tencent production dataset
//! (D3) — all proprietary or too large for this testbed — so we substitute
//! synthetic datasets with the *same field splits* (Table 1) and a hidden
//! teacher model that makes the label genuinely learnable by the student
//! DLRMs (see DESIGN.md §3 for why this preserves the paper's claims).
//!
//! Vertical partition semantics are enforced by construction: `PartyAData`
//! holds Party A's features only; `PartyBData` holds Party B's features
//! and the labels. The two sides are generated pre-aligned (the paper
//! assumes PSI alignment happened before training, §2.1) and mini-batches
//! are drawn from a shared-seed schedule so both parties always operate on
//! the same instance order without exchanging indices.

pub mod batcher;

use crate::util::rng::Pcg;

/// Dataset presets with Table-1 field splits, for error menus.
pub const VALID_DATASETS: &str = "criteo | avazu | d3";

/// Field counts per dataset (paper Table 1).
pub fn dataset_fields(name: &str) -> anyhow::Result<(usize, usize)> {
    match name {
        "criteo" => Ok((26, 13)),
        "avazu" => Ok((14, 8)),
        "d3" => Ok((25, 18)),
        _ => anyhow::bail!(
            "unknown dataset '{name}' — valid values: {VALID_DATASETS}"
        ),
    }
}

/// Column widths of [`PartyAData::vertical_split`] without the data:
/// near-equal contiguous slices, first `fields % k` one column wider.
/// The streaming data plane uses this to slice file columns per party
/// with the exact arithmetic the in-memory splitter uses.
pub fn split_widths(fields: usize, k: usize) -> anyhow::Result<Vec<usize>> {
    anyhow::ensure!(k >= 1, "vertical split needs ≥ 1 slice");
    anyhow::ensure!(
        k <= fields,
        "cannot split {fields} fields across {k} feature parties"
    );
    let base = fields / k;
    let extra = fields % k;
    Ok((0..k).map(|s| base + usize::from(s < extra)).collect())
}

/// Party A's vertical slice: features only, never labels.
#[derive(Debug, Clone)]
pub struct PartyAData {
    pub fields: usize,
    /// Row-major [n, fields] hashed ids.
    pub x: Vec<i32>,
    pub n: usize,
}

impl PartyAData {
    /// Split this feature slice vertically into `k` contiguous column
    /// slices — the K-party partition of the paper's Party-A fields.
    /// Widths are near-equal (the first `fields % k` slices get one
    /// extra column); every column lands in exactly one slice, so the
    /// union of the slices is the original data and no feature is
    /// shared between parties (the VFL premise). `k = 1` returns a
    /// clone of the data unchanged — hot-path callers (the trainer's
    /// two-party case) move the data instead of paying the copy.
    pub fn vertical_split(&self, k: usize)
                          -> anyhow::Result<Vec<PartyAData>> {
        anyhow::ensure!(k >= 1, "vertical split needs ≥ 1 slice");
        anyhow::ensure!(
            k <= self.fields,
            "cannot split {} fields across {k} feature parties",
            self.fields
        );
        if k == 1 {
            return Ok(vec![self.clone()]);
        }
        let base = self.fields / k;
        let extra = self.fields % k;
        let mut out = Vec::with_capacity(k);
        let mut off = 0usize;
        for s in 0..k {
            let w = base + usize::from(s < extra);
            let mut x = Vec::with_capacity(self.n * w);
            for row in 0..self.n {
                let start = row * self.fields + off;
                x.extend_from_slice(&self.x[start..start + w]);
            }
            out.push(PartyAData { fields: w, x, n: self.n });
            off += w;
        }
        debug_assert_eq!(off, self.fields);
        Ok(out)
    }
}

/// Party B's vertical slice: features + ground-truth labels.
#[derive(Debug, Clone)]
pub struct PartyBData {
    pub fields: usize,
    pub x: Vec<i32>,
    pub y: Vec<f32>,
    pub n: usize,
}

/// One fully-generated dataset (train + test splits for both parties).
#[derive(Debug, Clone)]
pub struct SynthDataset {
    pub name: String,
    pub vocab: usize,
    pub train_a: PartyAData,
    pub train_b: PartyBData,
    pub test_a: PartyAData,
    pub test_b: PartyBData,
}

/// Deterministic per-(field, id) teacher weight: a hash-seeded normal.
/// The teacher is a generalized linear model over the categorical ids
/// plus a low-rank pairwise interaction across the party boundary — so
/// neither party can fit the labels alone (the VFL premise), but the
/// joint embedding models can.
fn teacher_weight(seed: u64, field: u64, id: u64) -> f32 {
    let mut rng = Pcg::new(seed ^ 0x7ea3_c0de, (field << 32) | id);
    rng.next_normal()
}

fn teacher_factor(seed: u64, field: u64, id: u64, k: u64) -> f32 {
    let mut rng = Pcg::new(seed ^ 0xfac7_0e00, (field << 40) | (id << 8) | k);
    rng.next_normal()
}

const TEACHER_RANK: usize = 4;

/// Teacher logit for one instance. `xa`/`xb` are the per-field ids.
fn teacher_logit(seed: u64, xa: &[i32], xb: &[i32]) -> f32 {
    let fa = xa.len();
    let mut logit = 0.0f32;
    // Main effects, both parties.
    for (f, &id) in xa.iter().enumerate() {
        logit += teacher_weight(seed, f as u64, id as u64);
    }
    for (f, &id) in xb.iter().enumerate() {
        logit += teacher_weight(seed, (fa + f) as u64, id as u64);
    }
    // Cross-party low-rank interaction: <u(XA), v(XB)> — forces the model
    // to combine both parties' features (the VFL motivation in §1).
    for k in 0..TEACHER_RANK {
        let mut u = 0.0f32;
        let mut v = 0.0f32;
        for (f, &id) in xa.iter().enumerate() {
            u += teacher_factor(seed, f as u64, id as u64, k as u64);
        }
        for (f, &id) in xb.iter().enumerate() {
            v += teacher_factor(seed, (fa + f) as u64, id as u64, k as u64);
        }
        let norm = ((fa + xb.len()) as f32).sqrt();
        logit += (u / norm) * (v / norm);
    }
    // Scale to a reasonable logit spread (AUC ceiling ≈ 0.85-0.9 with
    // noise): the sum above has variance ≈ F_A+F_B+rank.
    logit / ((fa + xb.len() + TEACHER_RANK) as f32).sqrt() * 1.8
}

/// Zipf-ish id sampler: ids are drawn from a mixture of a small "hot" set
/// and the uniform tail, mimicking the skew of hashed CTR features.
fn sample_id(rng: &mut Pcg, vocab: usize) -> i32 {
    let hot = (vocab / 16).max(1);
    if rng.next_f32() < 0.5 {
        rng.gen_range(hot as u32) as i32
    } else {
        rng.gen_range(vocab as u32) as i32
    }
}

fn generate_split(
    seed: u64,
    stream: u64,
    n: usize,
    fields_a: usize,
    fields_b: usize,
    vocab: usize,
    label_noise: f64,
) -> (PartyAData, PartyBData) {
    let mut feat_rng = Pcg::new(seed, stream);
    let mut label_rng = Pcg::new(seed, stream ^ 0x5eed_1abe1);
    let mut xa = Vec::with_capacity(n * fields_a);
    let mut xb = Vec::with_capacity(n * fields_b);
    let mut y = Vec::with_capacity(n);
    let mut row_a = vec![0i32; fields_a];
    let mut row_b = vec![0i32; fields_b];
    for _ in 0..n {
        for slot in row_a.iter_mut() {
            *slot = sample_id(&mut feat_rng, vocab);
        }
        for slot in row_b.iter_mut() {
            *slot = sample_id(&mut feat_rng, vocab);
        }
        let logit = teacher_logit(seed, &row_a, &row_b);
        let p = 1.0 / (1.0 + (-logit as f64).exp());
        let mut label = (label_rng.next_f64() < p) as i32 as f32;
        if label_rng.next_f64() < label_noise {
            label = 1.0 - label;
        }
        xa.extend_from_slice(&row_a);
        xb.extend_from_slice(&row_b);
        y.push(label);
    }
    (
        PartyAData { fields: fields_a, x: xa, n },
        PartyBData { fields: fields_b, x: xb, y, n },
    )
}

impl SynthDataset {
    /// Generate a dataset. `vocab` must match the artifact preset (ids are
    /// fed straight into the embedding lookup).
    pub fn generate(
        name: &str,
        vocab: usize,
        train_n: usize,
        test_n: usize,
        label_noise: f64,
        seed: u64,
    ) -> anyhow::Result<Self> {
        let (fa, fb) = dataset_fields(name)?;
        let (train_a, train_b) =
            generate_split(seed, 1, train_n, fa, fb, vocab, label_noise);
        let (test_a, test_b) =
            generate_split(seed, 2, test_n, fa, fb, vocab, label_noise);
        Ok(SynthDataset {
            name: name.to_string(),
            vocab,
            train_a,
            train_b,
            test_a,
            test_b,
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tiny() -> SynthDataset {
        SynthDataset::generate("criteo", 100, 2000, 500, 0.05, 7).unwrap()
    }

    #[test]
    fn shapes_match_table1_splits() {
        let ds = tiny();
        assert_eq!(ds.train_a.fields, 26);
        assert_eq!(ds.train_b.fields, 13);
        assert_eq!(ds.train_a.x.len(), 2000 * 26);
        assert_eq!(ds.train_b.x.len(), 2000 * 13);
        assert_eq!(ds.train_b.y.len(), 2000);
        assert_eq!(ds.test_a.n, 500);
        let (fa, fb) = dataset_fields("avazu").unwrap();
        assert_eq!((fa, fb), (14, 8));
        assert!(dataset_fields("imagenet").is_err());
    }

    #[test]
    fn unknown_dataset_error_lists_the_menu() {
        let err = dataset_fields("imagenet").unwrap_err().to_string();
        assert!(err.contains("unknown dataset 'imagenet'"), "{err}");
        assert!(err.contains("criteo | avazu | d3"), "{err}");
    }

    #[test]
    fn split_widths_match_vertical_split() {
        let ds = tiny(); // criteo: 26 A-side fields
        for k in 1..=5 {
            let widths = split_widths(26, k).unwrap();
            let slices = ds.train_a.vertical_split(k).unwrap();
            assert_eq!(
                widths,
                slices.iter().map(|s| s.fields).collect::<Vec<_>>()
            );
            assert_eq!(widths.iter().sum::<usize>(), 26);
        }
        assert!(split_widths(26, 0).is_err());
        assert!(split_widths(4, 5).is_err());
    }

    #[test]
    fn deterministic_given_seed() {
        let a = tiny();
        let b = tiny();
        assert_eq!(a.train_a.x, b.train_a.x);
        assert_eq!(a.train_b.y, b.train_b.y);
        let c = SynthDataset::generate("criteo", 100, 2000, 500, 0.05, 8)
            .unwrap();
        assert_ne!(a.train_a.x, c.train_a.x);
    }

    #[test]
    fn ids_within_vocab() {
        let ds = tiny();
        assert!(ds.train_a.x.iter().all(|&i| (0..100).contains(&i)));
        assert!(ds.train_b.x.iter().all(|&i| (0..100).contains(&i)));
    }

    #[test]
    fn labels_are_binary_and_roughly_balanced() {
        let ds = tiny();
        let pos: f32 = ds.train_b.y.iter().sum();
        let rate = pos / ds.train_b.y.len() as f32;
        assert!(ds.train_b.y.iter().all(|&v| v == 0.0 || v == 1.0));
        assert!((0.3..0.7).contains(&rate), "positive rate {rate}");
    }

    #[test]
    fn teacher_is_learnable_bayes_auc() {
        // The teacher's own logit must rank the labels well (AUC ≫ 0.5),
        // otherwise no student could learn anything.
        let ds = tiny();
        let mut scored: Vec<(f32, f32)> = (0..ds.train_b.n)
            .map(|i| {
                let xa = &ds.train_a.x[i * 26..(i + 1) * 26];
                let xb = &ds.train_b.x[i * 13..(i + 1) * 13];
                (teacher_logit(7, xa, xb), ds.train_b.y[i])
            })
            .collect();
        scored.sort_by(|a, b| a.0.partial_cmp(&b.0).unwrap());
        // exact AUC via rank-sum
        let pos = scored.iter().filter(|(_, y)| *y == 1.0).count() as f64;
        let neg = scored.len() as f64 - pos;
        let rank_sum: f64 = scored
            .iter()
            .enumerate()
            .filter(|(_, (_, y))| *y == 1.0)
            .map(|(r, _)| (r + 1) as f64)
            .sum();
        let auc = (rank_sum - pos * (pos + 1.0) / 2.0) / (pos * neg);
        assert!(auc > 0.70, "teacher AUC {auc}");
    }

    #[test]
    fn cross_party_signal_exists() {
        // Party B alone (its own main effects) must not explain the label
        // as well as the joint teacher: check the interaction term moves
        // logits. Proxy: logits with XA zeroed differ substantially.
        let ds = tiny();
        let mut diff = 0.0f64;
        for i in 0..200 {
            let xa = &ds.train_a.x[i * 26..(i + 1) * 26];
            let xb = &ds.train_b.x[i * 13..(i + 1) * 13];
            let full = teacher_logit(7, xa, xb);
            let zeroed = teacher_logit(7, &vec![0; 26], xb);
            diff += (full - zeroed).abs() as f64;
        }
        assert!(diff / 200.0 > 0.1, "XA contributes nothing to the label");
    }

    #[test]
    fn vertical_split_partitions_columns_exactly() {
        let ds = tiny(); // criteo: 26 A-side fields
        let slices = ds.train_a.vertical_split(3).unwrap();
        // Near-equal widths: 26 → 9 + 9 + 8.
        assert_eq!(slices.iter().map(|s| s.fields).collect::<Vec<_>>(),
                   vec![9, 9, 8]);
        assert!(slices.iter().all(|s| s.n == ds.train_a.n));
        // Row 17 reassembles exactly from the slices, in column order.
        let row = 17usize;
        let mut rebuilt = Vec::new();
        for s in &slices {
            rebuilt.extend_from_slice(
                &s.x[row * s.fields..(row + 1) * s.fields]);
        }
        assert_eq!(rebuilt, &ds.train_a.x[row * 26..(row + 1) * 26]);
        // k = 1 is the identity (two-party path untouched).
        let one = ds.train_a.vertical_split(1).unwrap();
        assert_eq!(one.len(), 1);
        assert_eq!(one[0].x, ds.train_a.x);
        assert_eq!(one[0].fields, 26);
        // Degenerate splits are rejected.
        assert!(ds.train_a.vertical_split(0).is_err());
        assert!(ds.train_a.vertical_split(27).is_err());
    }

    #[test]
    fn id_distribution_is_skewed() {
        let ds = tiny();
        let hot = ds.train_a.x.iter().filter(|&&i| i < 100 / 16).count();
        let frac = hot as f64 / ds.train_a.x.len() as f64;
        assert!(frac > 0.4, "hot fraction {frac} — skew missing");
    }
}
