//! Shared-seed aligned mini-batch scheduler (paper §2.1, Data Management).
//!
//! Both parties construct a `BatchSchedule` from the same seed and epoch
//! counter, so batch `i` refers to the same instance rows on both sides
//! without any index exchange — exactly the paper's "sample the
//! mini-batches using the same random seed" protocol. The whole training
//! dataset is reshuffled every epoch (paper §3.2: shuffling ensures the
//! workset holds instances in random order).

use std::sync::Arc;

use crate::tensor::Tensor;
use crate::util::rng::Pcg;

use super::{PartyAData, PartyBData};

/// Epoch-scoped permutation of instance indices, chunked into batches.
#[derive(Debug, Clone)]
pub struct BatchSchedule {
    order: Vec<u32>,
    batch: usize,
}

impl BatchSchedule {
    /// Build the schedule for `epoch` over `n` instances. Deterministic in
    /// (seed, epoch): both parties call this independently and agree.
    pub fn new(seed: u64, epoch: u64, n: usize, batch: usize) -> Self {
        assert!(batch > 0 && n >= batch,
                "need at least one full batch (n={n}, batch={batch})");
        let mut order: Vec<u32> = (0..n as u32).collect();
        Pcg::new(seed ^ 0xba7c_4ed0, epoch).shuffle(&mut order);
        BatchSchedule { order, batch }
    }

    /// Number of full batches per epoch (the tail remainder is dropped —
    /// static HLO shapes require full batches).
    pub fn num_batches(&self) -> usize {
        self.order.len() / self.batch
    }

    /// Instance indices of batch `i`.
    pub fn indices(&self, i: usize) -> &[u32] {
        assert!(i < self.num_batches(), "batch index out of range");
        &self.order[i * self.batch..(i + 1) * self.batch]
    }
}

/// Iterator over the global batch sequence (epoch after epoch), tracking
/// the communication-round timestamp. Each party owns one, seeded alike.
#[derive(Debug)]
pub struct BatchCursor {
    seed: u64,
    n: usize,
    batch: usize,
    epoch: u64,
    next_in_epoch: usize,
    schedule: BatchSchedule,
}

impl BatchCursor {
    pub fn new(seed: u64, n: usize, batch: usize) -> Self {
        let schedule = BatchSchedule::new(seed, 0, n, batch);
        BatchCursor { seed, n, batch, epoch: 0, next_in_epoch: 0, schedule }
    }

    /// Indices of the next batch, advancing the cursor (and re-shuffling
    /// at epoch boundaries).
    pub fn next_indices(&mut self) -> Vec<u32> {
        if self.next_in_epoch >= self.schedule.num_batches() {
            self.epoch += 1;
            self.next_in_epoch = 0;
            self.schedule =
                BatchSchedule::new(self.seed, self.epoch, self.n, self.batch);
        }
        let idx = self.schedule.indices(self.next_in_epoch).to_vec();
        self.next_in_epoch += 1;
        idx
    }

    pub fn epoch(&self) -> u64 {
        self.epoch
    }
}

/// Reusable gather destination (DESIGN.md §4). Holds a handle to the
/// previous batch's shared buffer; when every other handle has been
/// dropped (refcount back to 1) and the batch geometry is unchanged, the
/// allocation is recycled in place — steady-state gathers in the
/// coordinator loops allocate nothing. While any consumer still holds the
/// previous tensor, a fresh buffer is allocated instead, so recycling is
/// invisible to correctness.
#[derive(Debug, Default)]
pub struct GatherScratch {
    x: Option<Arc<[i32]>>,
    y: Option<Arc<[f32]>>,
}

/// Recycle `slot`'s allocation when it is unique and the right size;
/// otherwise allocate fresh. Either way `fill` writes every element.
fn recycle<T: Copy + Default>(
    slot: &mut Option<Arc<[T]>>,
    n: usize,
    fill: impl FnOnce(&mut [T]),
) -> Arc<[T]> {
    if let Some(arc) = slot {
        if arc.len() == n {
            if let Some(buf) = Arc::get_mut(arc) {
                fill(buf);
                return arc.clone();
            }
        }
    }
    let mut v = vec![T::default(); n];
    fill(&mut v);
    let arc: Arc<[T]> = v.into();
    *slot = Some(arc.clone());
    arc
}

/// Copy `idx`'s rows of the row-major [n, f] table `src` into `out`
/// (shared by both parties' gathers).
fn gather_rows(src: &[i32], f: usize, idx: &[u32], out: &mut [i32]) {
    for (row, &i) in idx.iter().enumerate() {
        let i = i as usize;
        out[row * f..(row + 1) * f]
            .copy_from_slice(&src[i * f..(i + 1) * f]);
    }
}

/// Gather Party A's feature rows for a batch into an i32 [B, F] tensor.
pub fn gather_a(data: &PartyAData, idx: &[u32]) -> Tensor {
    gather_a_with(data, idx, &mut GatherScratch::default())
}

/// `gather_a` against a caller-held scratch, recycling the destination
/// buffer across calls once previous handles are dropped.
pub fn gather_a_with(data: &PartyAData, idx: &[u32],
                     scratch: &mut GatherScratch) -> Tensor {
    let f = data.fields;
    let x = recycle(&mut scratch.x, idx.len() * f,
                    |out| gather_rows(&data.x, f, idx, out));
    Tensor::i32(vec![idx.len(), f], x)
}

/// Gather Party B's feature rows + labels for a batch.
pub fn gather_b(data: &PartyBData, idx: &[u32]) -> (Tensor, Tensor) {
    gather_b_with(data, idx, &mut GatherScratch::default())
}

/// `gather_b` against a caller-held scratch (see [`gather_a_with`]).
pub fn gather_b_with(data: &PartyBData, idx: &[u32],
                     scratch: &mut GatherScratch) -> (Tensor, Tensor) {
    let f = data.fields;
    let x = recycle(&mut scratch.x, idx.len() * f,
                    |out| gather_rows(&data.x, f, idx, out));
    let y = recycle(&mut scratch.y, idx.len(), |out| {
        for (row, &i) in idx.iter().enumerate() {
            out[row] = data.y[i as usize];
        }
    });
    (Tensor::i32(vec![idx.len(), f], x), Tensor::f32(vec![idx.len()], y))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::SynthDataset;

    #[test]
    fn both_parties_agree_on_schedule() {
        let a = BatchSchedule::new(42, 3, 1000, 64);
        let b = BatchSchedule::new(42, 3, 1000, 64);
        for i in 0..a.num_batches() {
            assert_eq!(a.indices(i), b.indices(i));
        }
    }

    #[test]
    fn epochs_reshuffle() {
        let a = BatchSchedule::new(42, 0, 1000, 64);
        let b = BatchSchedule::new(42, 1, 1000, 64);
        assert_ne!(a.indices(0), b.indices(0));
    }

    #[test]
    fn schedule_is_a_partition() {
        let s = BatchSchedule::new(7, 0, 640, 64);
        let mut seen: Vec<u32> = (0..s.num_batches())
            .flat_map(|i| s.indices(i).to_vec())
            .collect();
        seen.sort_unstable();
        seen.dedup();
        assert_eq!(seen.len(), 640);
    }

    #[test]
    fn cursor_rolls_epochs() {
        let mut c = BatchCursor::new(1, 130, 64);
        assert_eq!(c.next_indices().len(), 64);
        assert_eq!(c.epoch(), 0);
        c.next_indices();
        // 130/64 = 2 batches per epoch; third call rolls over.
        c.next_indices();
        assert_eq!(c.epoch(), 1);
    }

    #[test]
    fn cursors_stay_aligned_across_epochs() {
        let mut a = BatchCursor::new(9, 300, 64);
        let mut b = BatchCursor::new(9, 300, 64);
        for _ in 0..20 {
            assert_eq!(a.next_indices(), b.next_indices());
        }
    }

    #[test]
    fn scratch_gather_matches_fresh_gather() {
        let ds = SynthDataset::generate("avazu", 50, 500, 100, 0.0, 3)
            .unwrap();
        let mut scratch = GatherScratch::default();
        for idx in [vec![0u32, 9, 3], vec![7u32, 7, 49], vec![1u32, 2, 3]] {
            let fresh_a = gather_a(&ds.train_a, &idx);
            let with_a = gather_a_with(&ds.train_a, &idx, &mut scratch);
            assert_eq!(fresh_a, with_a);
            let (fx, fy) = gather_b(&ds.train_b, &idx);
            let (wx, wy) = gather_b_with(&ds.train_b, &idx, &mut scratch);
            assert_eq!(fx, wx);
            assert_eq!(fy, wy);
        }
    }

    #[test]
    fn scratch_recycles_only_when_unreferenced() {
        use crate::tensor::Data;
        let ds = SynthDataset::generate("avazu", 50, 500, 100, 0.0, 3)
            .unwrap();
        let idx1 = vec![0u32, 1, 2];
        let idx2 = vec![3u32, 4, 5];
        let mut scratch = GatherScratch::default();
        let t1 = gather_a_with(&ds.train_a, &idx1, &mut scratch);
        let t1_copy = t1.clone();
        // t1 still alive → the second gather must NOT overwrite it.
        let t2 = gather_a_with(&ds.train_a, &idx2, &mut scratch);
        assert!(!t1.shares_data(&t2), "live tensor was overwritten");
        assert_eq!(t1, t1_copy, "live tensor contents changed");
        // Drop every outside handle; scratch now holds t2's buffer
        // uniquely and must recycle it for the next gather.
        let weak = match &t2.data {
            Data::I32(a) => std::sync::Arc::downgrade(a),
            _ => unreachable!("gather_a yields i32"),
        };
        drop(t1);
        drop(t1_copy);
        drop(t2);
        let t3 = gather_a_with(&ds.train_a, &idx1, &mut scratch);
        let recycled = match (&t3.data, weak.upgrade()) {
            (Data::I32(a), Some(prev)) => std::sync::Arc::ptr_eq(a, &prev),
            _ => false,
        };
        assert!(recycled, "scratch failed to recycle the allocation");
        assert_eq!(t3, gather_a(&ds.train_a, &idx1));
    }

    #[test]
    fn scratch_reallocates_on_geometry_change() {
        let ds = SynthDataset::generate("avazu", 50, 500, 100, 0.0, 3)
            .unwrap();
        let mut scratch = GatherScratch::default();
        let t1 = gather_a_with(&ds.train_a, &[0, 1, 2], &mut scratch);
        drop(t1);
        // Different batch size → new allocation, correct contents.
        let t2 = gather_a_with(&ds.train_a, &[5, 6], &mut scratch);
        assert_eq!(t2.shape, vec![2, ds.train_a.fields]);
        assert_eq!(t2, gather_a(&ds.train_a, &[5, 6]));
    }

    #[test]
    fn gather_extracts_aligned_rows() {
        let ds = SynthDataset::generate("avazu", 50, 500, 100, 0.0, 3)
            .unwrap();
        let idx = vec![5u32, 17, 3];
        let xa = gather_a(&ds.train_a, &idx);
        let (xb, y) = gather_b(&ds.train_b, &idx);
        assert_eq!(xa.shape, vec![3, 14]);
        assert_eq!(xb.shape, vec![3, 8]);
        assert_eq!(y.shape, vec![3]);
        // Row 1 of the gather == instance 17's raw features.
        assert_eq!(xa.row_f32(0).is_err(), true); // i32 tensor
        let xa_raw = xa.as_i32().unwrap();
        assert_eq!(&xa_raw[14..28], &ds.train_a.x[17 * 14..18 * 14]);
        assert_eq!(y.as_f32().unwrap()[1], ds.train_b.y[17]);
    }
}
