//! Shared-seed aligned mini-batch scheduler (paper §2.1, Data Management).
//!
//! Both parties construct a `BatchSchedule` from the same seed and epoch
//! counter, so batch `i` refers to the same instance rows on both sides
//! without any index exchange — exactly the paper's "sample the
//! mini-batches using the same random seed" protocol. The whole training
//! dataset is reshuffled every epoch (paper §3.2: shuffling ensures the
//! workset holds instances in random order).

use crate::tensor::Tensor;
use crate::util::rng::Pcg;

use super::{PartyAData, PartyBData};

/// Epoch-scoped permutation of instance indices, chunked into batches.
#[derive(Debug, Clone)]
pub struct BatchSchedule {
    order: Vec<u32>,
    batch: usize,
}

impl BatchSchedule {
    /// Build the schedule for `epoch` over `n` instances. Deterministic in
    /// (seed, epoch): both parties call this independently and agree.
    pub fn new(seed: u64, epoch: u64, n: usize, batch: usize) -> Self {
        assert!(batch > 0 && n >= batch,
                "need at least one full batch (n={n}, batch={batch})");
        let mut order: Vec<u32> = (0..n as u32).collect();
        Pcg::new(seed ^ 0xba7c_4ed0, epoch).shuffle(&mut order);
        BatchSchedule { order, batch }
    }

    /// Number of full batches per epoch (the tail remainder is dropped —
    /// static HLO shapes require full batches).
    pub fn num_batches(&self) -> usize {
        self.order.len() / self.batch
    }

    /// Instance indices of batch `i`.
    pub fn indices(&self, i: usize) -> &[u32] {
        assert!(i < self.num_batches(), "batch index out of range");
        &self.order[i * self.batch..(i + 1) * self.batch]
    }
}

/// Iterator over the global batch sequence (epoch after epoch), tracking
/// the communication-round timestamp. Each party owns one, seeded alike.
#[derive(Debug)]
pub struct BatchCursor {
    seed: u64,
    n: usize,
    batch: usize,
    epoch: u64,
    next_in_epoch: usize,
    schedule: BatchSchedule,
}

impl BatchCursor {
    pub fn new(seed: u64, n: usize, batch: usize) -> Self {
        let schedule = BatchSchedule::new(seed, 0, n, batch);
        BatchCursor { seed, n, batch, epoch: 0, next_in_epoch: 0, schedule }
    }

    /// Indices of the next batch, advancing the cursor (and re-shuffling
    /// at epoch boundaries).
    pub fn next_indices(&mut self) -> Vec<u32> {
        if self.next_in_epoch >= self.schedule.num_batches() {
            self.epoch += 1;
            self.next_in_epoch = 0;
            self.schedule =
                BatchSchedule::new(self.seed, self.epoch, self.n, self.batch);
        }
        let idx = self.schedule.indices(self.next_in_epoch).to_vec();
        self.next_in_epoch += 1;
        idx
    }

    pub fn epoch(&self) -> u64 {
        self.epoch
    }
}

/// Gather Party A's feature rows for a batch into an i32 [B, F] tensor.
pub fn gather_a(data: &PartyAData, idx: &[u32]) -> Tensor {
    let f = data.fields;
    let mut out = Vec::with_capacity(idx.len() * f);
    for &i in idx {
        let i = i as usize;
        out.extend_from_slice(&data.x[i * f..(i + 1) * f]);
    }
    Tensor::i32(vec![idx.len(), f], out)
}

/// Gather Party B's feature rows + labels for a batch.
pub fn gather_b(data: &PartyBData, idx: &[u32]) -> (Tensor, Tensor) {
    let f = data.fields;
    let mut xs = Vec::with_capacity(idx.len() * f);
    let mut ys = Vec::with_capacity(idx.len());
    for &i in idx {
        let i = i as usize;
        xs.extend_from_slice(&data.x[i * f..(i + 1) * f]);
        ys.push(data.y[i]);
    }
    (Tensor::i32(vec![idx.len(), f], xs), Tensor::f32(vec![idx.len()], ys))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::SynthDataset;

    #[test]
    fn both_parties_agree_on_schedule() {
        let a = BatchSchedule::new(42, 3, 1000, 64);
        let b = BatchSchedule::new(42, 3, 1000, 64);
        for i in 0..a.num_batches() {
            assert_eq!(a.indices(i), b.indices(i));
        }
    }

    #[test]
    fn epochs_reshuffle() {
        let a = BatchSchedule::new(42, 0, 1000, 64);
        let b = BatchSchedule::new(42, 1, 1000, 64);
        assert_ne!(a.indices(0), b.indices(0));
    }

    #[test]
    fn schedule_is_a_partition() {
        let s = BatchSchedule::new(7, 0, 640, 64);
        let mut seen: Vec<u32> = (0..s.num_batches())
            .flat_map(|i| s.indices(i).to_vec())
            .collect();
        seen.sort_unstable();
        seen.dedup();
        assert_eq!(seen.len(), 640);
    }

    #[test]
    fn cursor_rolls_epochs() {
        let mut c = BatchCursor::new(1, 130, 64);
        assert_eq!(c.next_indices().len(), 64);
        assert_eq!(c.epoch(), 0);
        c.next_indices();
        // 130/64 = 2 batches per epoch; third call rolls over.
        c.next_indices();
        assert_eq!(c.epoch(), 1);
    }

    #[test]
    fn cursors_stay_aligned_across_epochs() {
        let mut a = BatchCursor::new(9, 300, 64);
        let mut b = BatchCursor::new(9, 300, 64);
        for _ in 0..20 {
            assert_eq!(a.next_indices(), b.next_indices());
        }
    }

    #[test]
    fn gather_extracts_aligned_rows() {
        let ds = SynthDataset::generate("avazu", 50, 500, 100, 0.0, 3)
            .unwrap();
        let idx = vec![5u32, 17, 3];
        let xa = gather_a(&ds.train_a, &idx);
        let (xb, y) = gather_b(&ds.train_b, &idx);
        assert_eq!(xa.shape, vec![3, 14]);
        assert_eq!(xb.shape, vec![3, 8]);
        assert_eq!(y.shape, vec![3]);
        // Row 1 of the gather == instance 17's raw features.
        assert_eq!(xa.row_f32(0).is_err(), true); // i32 tensor
        let xa_raw = xa.as_i32().unwrap();
        assert_eq!(&xa_raw[14..28], &ds.train_a.x[17 * 14..18 * 14]);
        assert_eq!(y.as_f32().unwrap()[1], ds.train_b.y[17]);
    }
}
