//! Minimal property-based testing framework (proptest is unavailable
//! offline).
//!
//! Philosophy: a property is a function from a seeded PRNG to a
//! `Result<(), String>`; the runner executes it across many seeds and, on
//! failure, reports the failing seed so the case can be replayed under a
//! debugger (`CELU_PROP_SEED=<n>` pins the runner to one seed). No
//! shrinking — cases are kept small by construction instead.

use crate::util::rng::Pcg;

/// Number of random cases per property (override with CELU_PROP_CASES).
pub fn default_cases() -> u64 {
    std::env::var("CELU_PROP_CASES")
        .ok()
        .and_then(|s| s.parse().ok())
        .unwrap_or(128)
}

/// Run `prop` across seeds; panic with the failing seed on first failure.
pub fn check<F>(name: &str, mut prop: F)
where
    F: FnMut(&mut Pcg) -> Result<(), String>,
{
    if let Ok(pin) = std::env::var("CELU_PROP_SEED") {
        let seed: u64 = pin.parse().expect("CELU_PROP_SEED must be u64");
        let mut rng = Pcg::new(seed, 0x9e3779b97f4a7c15);
        if let Err(msg) = prop(&mut rng) {
            panic!("property '{name}' failed at pinned seed {seed}: {msg}");
        }
        return;
    }
    for seed in 0..default_cases() {
        let mut rng = Pcg::new(seed, 0x9e3779b97f4a7c15);
        if let Err(msg) = prop(&mut rng) {
            panic!(
                "property '{name}' failed at seed {seed}: {msg}\n\
                 replay with CELU_PROP_SEED={seed}"
            );
        }
    }
}

/// Assertion helpers returning Result for use inside properties.
#[macro_export]
macro_rules! prop_assert {
    ($cond:expr, $($fmt:tt)*) => {
        if !$cond {
            return Err(format!($($fmt)*));
        }
    };
}

#[macro_export]
macro_rules! prop_assert_eq {
    ($a:expr, $b:expr) => {{
        let (a, b) = (&$a, &$b);
        if a != b {
            return Err(format!(
                "{} != {} ({:?} vs {:?})",
                stringify!($a), stringify!($b), a, b
            ));
        }
    }};
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passing_property_completes() {
        check("sum-commutes", |rng| {
            let a = rng.gen_range(1000) as i64;
            let b = rng.gen_range(1000) as i64;
            prop_assert_eq!(a + b, b + a);
            Ok(())
        });
    }

    #[test]
    #[should_panic(expected = "failed at seed")]
    fn failing_property_reports_seed() {
        check("always-false", |rng| {
            let x = rng.gen_range(10);
            prop_assert!(x < 5, "x={x} not < 5");
            Ok(())
        });
    }
}
