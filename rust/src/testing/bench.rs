//! Micro-benchmark harness (criterion is unavailable offline).
//!
//! `cargo bench` targets are plain `harness = false` binaries built on
//! this runner: fixed warmup, adaptive iteration count targeting a
//! minimum measurement window, and a compact report (mean / p50 / min /
//! throughput). Deliberately simple — no outlier rejection, no HTML —
//! but deterministic and dependency-free.

use std::time::{Duration, Instant};

/// One benchmark measurement.
#[derive(Debug, Clone)]
pub struct BenchResult {
    pub name: String,
    pub iters: u64,
    pub mean: Duration,
    pub p50: Duration,
    pub min: Duration,
}

impl BenchResult {
    pub fn report(&self) {
        println!(
            "{:<44} {:>10} iters  mean {:>12}  p50 {:>12}  min {:>12}",
            self.name,
            self.iters,
            fmt_dur(self.mean),
            fmt_dur(self.p50),
            fmt_dur(self.min)
        );
    }

    /// Report with an ops/sec-style throughput line (e.g. elements).
    pub fn report_throughput(&self, units_per_iter: f64, unit: &str) {
        let per_sec = units_per_iter / self.mean.as_secs_f64();
        println!(
            "{:<44} mean {:>12}  min {:>12}  {:>14.0} {unit}/s",
            self.name,
            fmt_dur(self.mean),
            fmt_dur(self.min),
            per_sec
        );
    }
}

pub fn fmt_dur(d: Duration) -> String {
    let ns = d.as_nanos();
    if ns < 1_000 {
        format!("{ns} ns")
    } else if ns < 1_000_000 {
        format!("{:.2} µs", ns as f64 / 1e3)
    } else if ns < 1_000_000_000 {
        format!("{:.2} ms", ns as f64 / 1e6)
    } else {
        format!("{:.3} s", ns as f64 / 1e9)
    }
}

/// Benchmark `f`, auto-scaling iterations to fill ~`window`.
pub fn bench<F: FnMut()>(name: &str, window: Duration, mut f: F)
                         -> BenchResult {
    // Warmup + calibration.
    let cal_start = Instant::now();
    f();
    let once = cal_start.elapsed().max(Duration::from_nanos(20));
    let iters = (window.as_secs_f64() / once.as_secs_f64())
        .clamp(1.0, 1e7) as u64;

    let mut samples = Vec::with_capacity(iters as usize);
    for _ in 0..iters {
        let s = Instant::now();
        f();
        samples.push(s.elapsed());
    }
    samples.sort();
    let total: Duration = samples.iter().sum();
    BenchResult {
        name: name.to_string(),
        iters,
        mean: total / iters as u32,
        p50: samples[samples.len() / 2],
        min: samples[0],
    }
}

/// Convenience: bench with the default 1-second window and print.
pub fn run<F: FnMut()>(name: &str, f: F) -> BenchResult {
    let r = bench(name, Duration::from_secs(1), f);
    r.report();
    r
}

/// Section header for bench output.
pub fn section(title: &str) {
    println!("\n### {title}");
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bench_measures_something() {
        let r = bench("spin", Duration::from_millis(20), || {
            std::hint::black_box((0..1000).sum::<u64>());
        });
        assert!(r.iters >= 1);
        assert!(r.min <= r.mean);
        assert!(r.p50 >= r.min);
    }

    #[test]
    fn fmt_dur_ranges() {
        assert!(fmt_dur(Duration::from_nanos(5)).contains("ns"));
        assert!(fmt_dur(Duration::from_micros(5)).contains("µs"));
        assert!(fmt_dur(Duration::from_millis(5)).contains("ms"));
        assert!(fmt_dur(Duration::from_secs(5)).contains(" s"));
    }
}
