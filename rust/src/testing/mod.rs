//! Test-support substrates (compiled into the library so integration
//! tests, examples and benches can share them).

pub mod bench;
pub mod prop;
