//! K = 3 chaos role matrix — kill any role mid-run, resume it, verify
//! byte parity against an undisturbed reference.
//!
//! The CI proof of the *symmetric* fault-tolerance story (DESIGN.md
//! §8/§9): run with `--kill <role>`, this binary re-executes itself as
//! three OS processes over loopback TCP — a supervised label party
//! (bounded straggler waits + a live re-admission point) and two
//! feature dialers — then kills the named role at a fixed round and
//! restarts it from its on-disk snapshot:
//!
//! - `--kill feature1` / `--kill feature2`: the victim writes a
//!   [`FeatureSnapshot`] at every round boundary and **exits** right
//!   after sending its round-`DIE_AFTER` activation (its in-flight
//!   round). The label observes the dead lane, emits `PeerLost`, and
//!   keeps stepping on cached stale statistics. The orchestrator
//!   relaunches the victim with `--resume <ckpt>`: it restores the
//!   snapshot's state, re-dials with `Rejoin{last_round}`, consumes
//!   the replayed in-flight derivative, and finishes in lock-step.
//!   Meanwhile the *surviving* feature party deliberately straggles
//!   one round — its links must stay **byte-identical** to the
//!   undisturbed in-proc reference. P1 is the fp16 lane and P2 the
//!   identity lane, so the matrix covers a compressed and an
//!   uncompressed victim.
//! - `--kill label`: the label writes a [`SessionSnapshot`] at the
//!   crash boundary and exits without any teardown. Both features
//!   survive the outage by re-dialing `Rejoin` with their completed
//!   round; the relaunched label (`--resume <ckpt>`) re-admits them at
//!   the snapshot round and the run completes. Every post-restart link
//!   segment must be byte-identical, per round, to the reference.
//!
//! Every scenario asserts round-count parity with the reference and
//! per-link `(wire, raw, msgs)` byte equality on surviving links; the
//! whole binary is artifact-free (no XLA, no model) so it runs on a
//! bare CI runner.
//!
//!     cargo run --release --example chaos_k3 -- --kill feature2
//!     cargo run --release --example chaos_k3 -- --kill feature1
//!     cargo run --release --example chaos_k3 -- --kill label

use std::io::BufRead;
use std::sync::Arc;
use std::time::Duration;

use celu_vfl::compress::{self, CodecKind};
use celu_vfl::config::{RunConfig, WanProfile};
use celu_vfl::protocol::{outbound_stats, Lane, Message,
                         FRAME_V2_OVERHEAD};
use celu_vfl::session::bootstrap::{inproc_mesh, rejoin_dial,
                                   SessionDialer, SessionListener};
use celu_vfl::session::checkpoint::{FeatureSnapshot, LinkCodecState,
                                    SessionSnapshot};
use celu_vfl::session::supervisor::{session_epoch, LaneSet};
use celu_vfl::session::{Link, PartyId, LABEL_PARTY};
use celu_vfl::tensor::Tensor;
use celu_vfl::transport::Transport;
use celu_vfl::util::cli::Cli;

const ROUNDS: u64 = 14;
const BATCH: usize = 16;
const Z_DIM: usize = 4;
const STRAGGLER_MS: u64 = 250;
/// A killed feature party's in-flight round when it dies.
const DIE_AFTER: u64 = 3;
/// The label's last completed round in the `--kill label` scenario.
const KILL_LABEL_AFTER: u64 = 5;
/// The surviving feature party sleeps through this round to force a
/// straggler timeout on top of the outage.
const STRAGGLE_ROUND: u64 = 8;
const JOIN_TIMEOUT: Duration = Duration::from_secs(20);

/// The session under test: K=3, supervised; party 1 compresses fp16
/// while party 2 stays uncompressed, so the run also covers join-time
/// codec pre-negotiation (no Hello frames anywhere) and mixed per-link
/// codecs under degradation.
///
/// The simulated WAN matters here: degraded rounds are paced by the
/// *live* lanes, so with instant links the label would finish every
/// remaining round in microseconds and the relaunched victim would
/// find a dead listener. An 80 ms RTT (~40 ms per send, charged
/// identically in the in-proc reference, so byte parity is unaffected)
/// makes each round take ~80 ms — the rejoin deterministically lands
/// mid-run.
fn smoke_cfg() -> RunConfig {
    let mut cfg = RunConfig::quick();
    cfg.parties = 3;
    cfg.wan = WanProfile { bandwidth_mbps: 0.0, rtt_ms: 80.0,
                           gateway_ms: 0.0 };
    cfg.compress = CodecKind::Identity;
    cfg.party_compress = vec![(1, CodecKind::Fp16)];
    cfg.straggler_wait_ms = STRAGGLER_MS;
    cfg.validate().expect("smoke config invalid");
    cfg
}

/// Deterministic stand-in for a bottom model's activations — identical
/// in every process and in the in-proc reference run.
fn synth(party: u16, round: u64) -> Tensor {
    let v: Vec<f32> = (0..BATCH * Z_DIM)
        .map(|i| {
            ((i as f32 * 0.31 + party as f32 * 1.7 + round as f32 * 0.13)
                .sin())
                * 0.8
        })
        .collect();
    Tensor::f32(vec![BATCH, Z_DIM], v)
}

/// The deterministic "model state" a feature party checkpoints after
/// completing `round` rounds — the relaunched process asserts it reads
/// back exactly these tensors.
fn snapshot_state(party: u16, round: u64) -> (Vec<Tensor>, Vec<Tensor>) {
    (vec![synth(party, round)], vec![synth(party + 7, round)])
}

/// One feature party's traffic from `start` to ROUNDS. The codec is
/// pre-negotiated from the link's join-time mask — no Hello. `die`
/// exits the process right after sending that round's activation;
/// `straggle` sleeps past the label's wait window before sending;
/// `ckpt_dir` writes a [`FeatureSnapshot`] at every round boundary
/// (checkpoint-every = 1), exactly like the production comm worker.
#[allow(clippy::too_many_arguments)]
fn feature_rounds(party: PartyId, transport: &Arc<dyn Transport>,
                  codec: CodecKind, start: u64, die: Option<u64>,
                  straggle: Option<u64>, ckpt_dir: Option<&str>,
                  epoch: u32) -> anyhow::Result<()> {
    for round in start..ROUNDS {
        if straggle == Some(round) {
            std::thread::sleep(Duration::from_millis(STRAGGLER_MS + 200));
        }
        let za = synth(party.0, round);
        let (msg, _za) = outbound_stats(codec, Lane::Activation, round, za)?;
        transport.send(msg)?;
        if die == Some(round) {
            // Hard exit mid-round: the in-flight activation is on the
            // wire, the derivative never gets consumed.
            std::process::exit(0);
        }
        match transport.recv()?.into_plain()? {
            Message::Derivative { round: r, .. } => {
                anyhow::ensure!(r == round, "round skew on {party}: \
                                             got {r}, at {round}");
            }
            other => anyhow::bail!("unexpected {:?}", other.tag()),
        }
        if let Some(dir) = ckpt_dir {
            let (params, accs) = snapshot_state(party.0, round + 1);
            FeatureSnapshot {
                epoch,
                round: round + 1,
                parties: 3,
                party: party.0,
                codec,
                params,
                accs,
            }
            .save(dir)?;
        }
    }
    match transport.recv()? {
        Message::Shutdown => Ok(()),
        other => anyhow::bail!("expected Shutdown, got {:?}", other.tag()),
    }
}

fn negotiated(cfg: &RunConfig, party: PartyId, link: &Link) -> CodecKind {
    compress::negotiate(cfg.codec_for(party.0), link.peer_codecs)
}

/// The supervised label loop over a [`LaneSet`] — the same machinery
/// `coordinator::label_party` drives, minus the model. `die_after`
/// writes a boundary [`SessionSnapshot`] to `ckpt_dir` after that
/// round's fan-out and hard-exits (the `--kill label` crash point).
fn label_rounds(cfg: &RunConfig, lanes: &mut LaneSet, start: u64,
                pinned: Option<&[LinkCodecState]>, die_after: Option<u64>,
                ckpt_dir: Option<&str>) -> anyhow::Result<(u64, u64)> {
    lanes.handshake(cfg, pinned)?;
    let mut stale_rounds = 0u64;
    for round in start..ROUNDS {
        let inputs = lanes.collect(round)?;
        if inputs.iter().any(|i| !i.is_fresh()) {
            stale_rounds += 1;
        }
        let zs: Vec<Tensor> = inputs
            .iter()
            .filter_map(|i| i.tensor().cloned())
            .collect();
        let zsum = Tensor::sum_f32(&zs)?;
        // Stand-in for the exact step: ∇Z = 0.1 · ΣZ.
        let dza = Tensor::f32(
            zsum.shape.clone(),
            zsum.as_f32()?.iter().map(|x| 0.1 * x).collect::<Vec<_>>(),
        );
        let _views = lanes.stage_derivatives(round, &dza)?;
        lanes.send_staged(round)?;
        if die_after == Some(round) {
            // Crash point: persist the boundary snapshot (no model in
            // this smoke — codec states are what resumption needs),
            // then die hard: no Shutdown, no lane teardown.
            let dir = ckpt_dir
                .ok_or_else(|| anyhow::anyhow!(
                    "--die-after on the label needs --ckpt-dir"))?;
            let path = SessionSnapshot {
                epoch: lanes.epoch(),
                round: round + 1,
                parties: cfg.parties as u16,
                links: lanes.codec_states(),
                params: Vec::new(),
                accs: Vec::new(),
            }
            .save(dir)?;
            println!("CKPT {path}");
            std::io::Write::flush(&mut std::io::stdout())?;
            std::process::exit(0);
        }
    }
    lanes.shutdown();
    Ok((ROUNDS, stale_rounds))
}

fn link_line(src: u16, dst: u16,
             s: &celu_vfl::transport::LinkStats) -> String {
    format!("LINK {src} {dst} {} {} {}", s.bytes, s.raw_bytes, s.messages)
}

// ---- the roles -------------------------------------------------------------

fn run_label(listen: &str, die_after: Option<u64>, ckpt_dir: Option<&str>,
             resume: Option<&str>) -> anyhow::Result<()> {
    let cfg = smoke_cfg();
    let (listener, snap) = if let Some(path) = resume {
        let snap = SessionSnapshot::load(path)?;
        // The relaunch must reclaim the exact address the dialers
        // know; retry while the dead process's socket drains.
        let deadline = std::time::Instant::now() + JOIN_TIMEOUT;
        let listener = loop {
            match SessionListener::bind(listen) {
                Ok(l) => break l,
                Err(e) => {
                    anyhow::ensure!(
                        std::time::Instant::now() < deadline,
                        "rebind of {listen} failed: {e:#}"
                    );
                    std::thread::sleep(Duration::from_millis(25));
                }
            }
        };
        (listener
             .with_timeout(JOIN_TIMEOUT)
             .with_resume(snap.epoch, snap.round),
         Some(snap))
    } else {
        (SessionListener::bind(listen)?.with_timeout(JOIN_TIMEOUT), None)
    };
    println!("ADDR {}", listener.local_addr()?);
    use std::io::Write;
    std::io::stdout().flush()?;
    let (links, readmission, _epoch, start) =
        listener.establish_supervised(&cfg)?;
    let mut lanes = LaneSet::new(&cfg, &links, Some(readmission));
    let (rounds, stale_rounds) = label_rounds(
        &cfg, &mut lanes, start,
        snap.as_ref().map(|s| &s.links[..]), die_after, ckpt_dir)?;
    println!("ROUNDS {rounds}");
    println!("STALE {stale_rounds}");
    println!("REJOINS {}", lanes.total_rejoins());
    for e in lanes.take_events() {
        println!(
            "EVENT {} {} {}",
            e.kind(),
            e.party().map(|p| p.0 as i64).unwrap_or(-1),
            e.round()
        );
    }
    for (peer, s) in lanes.link_stats() {
        println!("{}", link_line(LABEL_PARTY.0, peer.0, &s));
    }
    Ok(())
}

fn run_feature(party: u16, connect: &str, die: Option<u64>,
               straggle: Option<u64>, ckpt_dir: Option<&str>)
               -> anyhow::Result<()> {
    let cfg = smoke_cfg();
    let (link, start) = SessionDialer::new(connect, PartyId(party))
        .with_timeout(JOIN_TIMEOUT)
        .establish_resumable(&cfg)?;
    anyhow::ensure!(start == 0, "fresh join resumed at {start}");
    let codec = negotiated(&cfg, PartyId(party), &link);
    feature_rounds(PartyId(party), &link.transport, codec, 0, die,
                   straggle, ckpt_dir, session_epoch(cfg.seed))?;
    println!("{}", link_line(party, LABEL_PARTY.0,
                             &link.transport.stats()));
    Ok(())
}

/// Relaunched feature victim: restore the snapshot, re-dial with
/// `Rejoin{last_round = snapshot round}`, consume the replayed
/// in-flight derivative, resume at the acked round with the snapshot's
/// pinned codec.
fn run_rejoiner(party: u16, connect: &str, last_round: u64,
                resume: Option<&str>) -> anyhow::Result<()> {
    let cfg = smoke_cfg();
    let epoch = session_epoch(cfg.seed);
    let (last_round, codec) = if let Some(path) = resume {
        let snap = FeatureSnapshot::load(path)?;
        anyhow::ensure!(
            snap.party == party && snap.epoch == epoch,
            "{path} does not belong to this party/session"
        );
        // The restored "model": the snapshot must round-trip exactly
        // the tensors the dying process wrote at that boundary.
        let (params, accs) = snapshot_state(party, snap.round);
        anyhow::ensure!(
            snap.params == params && snap.accs == accs,
            "snapshot state diverged from what was written"
        );
        println!("RESTORED {} {}", snap.round, snap.codec.label());
        (snap.round, snap.codec)
    } else {
        // Legacy fallback: no snapshot, claim the round from the CLI
        // and re-derive the codec from this build's mask (see
        // SessionDialer::establish_resumable for the rationale).
        (last_round,
         compress::negotiate(cfg.codec_for(party),
                             Some(compress::supported_mask())))
    };
    let (transport, resume_round, replays) = rejoin_dial(
        connect, PartyId(party), &cfg, epoch, last_round, JOIN_TIMEOUT)?;
    for _ in 0..replays {
        match transport.recv()?.into_plain()? {
            Message::Derivative { round: r, .. } => {
                anyhow::ensure!(
                    r == last_round,
                    "replay carries round {r}, expected {last_round}"
                );
            }
            other => anyhow::bail!("unexpected replay {:?}", other.tag()),
        }
    }
    let transport = &transport;
    feature_rounds(PartyId(party), transport, codec, resume_round, None,
                   None, None, epoch)?;
    println!("RESUMED {resume_round} {replays}");
    println!("{}", link_line(party, LABEL_PARTY.0, &transport.stats()));
    Ok(())
}

/// A feature party that survives a *label* crash: on transport failure
/// it re-dials the relaunched listener with `Rejoin{last_round = its
/// completed rounds}` and resumes where the label's snapshot says.
/// Prints the post-restart link segment (the fresh transport's stats).
fn run_feature_resilient(party: u16, connect: &str) -> anyhow::Result<()> {
    let cfg = smoke_cfg();
    let pid = PartyId(party);
    let (link, start) = SessionDialer::new(connect, pid)
        .with_timeout(JOIN_TIMEOUT)
        .establish_resumable(&cfg)?;
    anyhow::ensure!(start == 0, "fresh join resumed at {start}");
    let codec = negotiated(&cfg, pid, &link);
    let epoch = session_epoch(cfg.seed);
    let mut transport: Arc<dyn Transport> = link.transport.clone();
    let mut resumed: Option<(u64, u64)> = None;
    let mut round = 0u64;
    while round < ROUNDS {
        let za = synth(party, round);
        let (msg, _) = outbound_stats(codec, Lane::Activation, round, za)?;
        let dead = match transport.send(msg) {
            Err(_) => true,
            Ok(()) => match transport.recv() {
                Err(_) => true,
                Ok(m) => match m.into_plain()? {
                    Message::Derivative { round: r, .. } => {
                        anyhow::ensure!(r == round,
                                        "round skew: {r} at {round}");
                        false
                    }
                    other => anyhow::bail!("unexpected {:?}",
                                           other.tag()),
                },
            },
        };
        if !dead {
            round += 1;
            continue;
        }
        // The label died; its relaunch re-admits Rejoins claiming our
        // completed rounds and acks the snapshot's resume round.
        let (tr, resume, replays) = rejoin_dial(
            connect, pid, &cfg, epoch, round, JOIN_TIMEOUT)?;
        anyhow::ensure!(replays == 0,
                        "a restarted label has nothing to replay \
                         ({replays})");
        transport = tr;
        resumed = Some((resume, replays as u64));
        round = resume;
    }
    loop {
        match transport.recv() {
            Ok(Message::Shutdown) | Err(_) => break,
            Ok(_) => {}
        }
    }
    let (resume, replays) = resumed
        .ok_or_else(|| anyhow::anyhow!("the label never went down"))?;
    println!("RESUMED {resume} {replays}");
    println!("{}", link_line(party, LABEL_PARTY.0, &transport.stats()));
    Ok(())
}

// ---- undisturbed reference -------------------------------------------------

type LinkMap = std::collections::BTreeMap<(u16, u16), (u64, u64, u64)>;

fn parse_link_lines(text: &str, into: &mut LinkMap) -> anyhow::Result<()> {
    for line in text.lines() {
        let Some(rest) = line.trim().strip_prefix("LINK ") else {
            continue;
        };
        let f: Vec<u64> = rest
            .split_whitespace()
            .map(|x| x.parse::<u64>())
            .collect::<Result<_, _>>()
            .map_err(|e| anyhow::anyhow!("bad LINK line '{line}': {e}"))?;
        anyhow::ensure!(f.len() == 5, "bad LINK line '{line}'");
        let prev = into.insert((f[0] as u16, f[1] as u16),
                               (f[2], f[3], f[4]));
        anyhow::ensure!(prev.is_none(),
                        "duplicate LINK row {}→{}", f[0], f[1]);
    }
    Ok(())
}

/// Undisturbed reference over the in-proc bootstrap: same LaneSet, no
/// kill, no straggler.
fn run_inproc_reference() -> anyhow::Result<LinkMap> {
    let cfg = smoke_cfg();
    let (label_bs, feature_bs) = inproc_mesh(&cfg);
    let mut handles = Vec::new();
    let mut feature_transports = Vec::new();
    let mut label_links: Vec<Link> = Vec::new();
    let epoch = session_epoch(cfg.seed);
    for (i, bs) in feature_bs.into_iter().enumerate() {
        let party = PartyId(i as u16 + 1);
        let cfg_f = cfg.clone();
        let link = {
            use celu_vfl::session::bootstrap::MeshBootstrap;
            bs.establish(&cfg)?.swap_remove(0)
        };
        let codec = negotiated(&cfg_f, party, &link);
        let transport = link.transport.clone();
        feature_transports.push((party, transport.clone()));
        handles.push(std::thread::spawn(move || {
            feature_rounds(party, &transport, codec, 0, None, None, None,
                           epoch)
        }));
    }
    {
        use celu_vfl::session::bootstrap::MeshBootstrap;
        label_links.extend(label_bs.establish(&cfg)?);
    }
    let mut lanes = LaneSet::new(&cfg, &label_links, None);
    let (rounds, stale) = label_rounds(&cfg, &mut lanes, 0, None, None,
                                       None)?;
    anyhow::ensure!(rounds == ROUNDS && stale == 0,
                    "reference run degraded ({rounds} rounds, {stale} \
                     stale)");
    for h in handles {
        h.join().expect("feature thread panicked")?;
    }
    let mut map = LinkMap::new();
    for (peer, s) in lanes.link_stats() {
        map.insert((LABEL_PARTY.0, peer.0),
                   (s.bytes, s.raw_bytes, s.messages));
    }
    for (party, t) in feature_transports {
        let s = t.stats();
        map.insert((party.0, LABEL_PARTY.0),
                   (s.bytes, s.raw_bytes, s.messages));
    }
    Ok(map)
}

// ---- orchestrators ---------------------------------------------------------

/// Read child stdout lines until the `ADDR ` announcement.
fn read_addr(out: &mut impl BufRead) -> anyhow::Result<String> {
    loop {
        let mut line = String::new();
        anyhow::ensure!(
            out.read_line(&mut line)? > 0,
            "label process exited before announcing its address"
        );
        if let Some(a) = line.trim().strip_prefix("ADDR ") {
            return Ok(a.to_string());
        }
    }
}

fn grab_line(text: &str, prefix: &str) -> anyhow::Result<u64> {
    text.lines()
        .find_map(|l| l.trim().strip_prefix(prefix))
        .and_then(|v| v.split_whitespace().next()?.parse::<u64>().ok())
        .ok_or_else(|| anyhow::anyhow!("no {prefix} line"))
}

/// Per-frame wire/raw cost of one statistics frame under `codec` —
/// fixed across rounds (same shape every round), so per-round byte
/// parity reduces to arithmetic on these.
fn frame_cost(codec: CodecKind, party: u16) -> anyhow::Result<(u64, u64)> {
    let (msg, _) = outbound_stats(codec, Lane::Activation, 0,
                                  synth(party, 0))?;
    Ok(((msg.wire_bytes() + FRAME_V2_OVERHEAD) as u64,
        (msg.raw_bytes() + FRAME_V2_OVERHEAD) as u64))
}

fn shutdown_cost() -> u64 {
    (Message::Shutdown.wire_bytes() + FRAME_V2_OVERHEAD) as u64
}

/// `--kill feature1` / `--kill feature2`: kill one feature party at
/// its fault point, restart it from its own snapshot, assert parity.
fn orchestrate_feature_kill(victim: u16) -> anyhow::Result<()> {
    use std::process::{Command, Stdio};
    anyhow::ensure!(victim == 1 || victim == 2, "bad victim {victim}");
    let survivor: u16 = 3 - victim;
    let cfg = smoke_cfg();
    let victim_codec = compress::negotiate(
        cfg.codec_for(victim), Some(compress::supported_mask()));

    let expected = run_inproc_reference()?;
    println!("in-proc reference complete ({} links)", expected.len());

    let dir = std::env::temp_dir().join(format!(
        "celu_chaos_f{victim}_{}", std::process::id()));
    let _ = std::fs::remove_dir_all(&dir);
    let dir_s = dir.to_string_lossy().into_owned();

    let exe = std::env::current_exe()?;
    let mut label = Command::new(&exe)
        .args(["--role", "label", "--listen", "127.0.0.1:0"])
        .stdout(Stdio::piped())
        .spawn()?;
    let mut label_out =
        std::io::BufReader::new(label.stdout.take().expect("label stdout"));
    let addr = read_addr(&mut label_out)?;
    println!("label listening at {addr}; killing feature P{victim}");

    // The survivor runs the full session with one deliberate straggle;
    // the victim checkpoints every boundary and dies mid-round.
    let surv = Command::new(&exe)
        .args(["--role", "feature", "--party", &survivor.to_string(),
               "--connect", addr.as_str(),
               "--straggle-round", &STRAGGLE_ROUND.to_string()])
        .stdout(Stdio::piped())
        .spawn()?;
    let vict = Command::new(&exe)
        .args(["--role", "feature", "--party", &victim.to_string(),
               "--connect", addr.as_str(),
               "--die-after", &DIE_AFTER.to_string(),
               "--ckpt-dir", &dir_s])
        .stdout(Stdio::piped())
        .spawn()?;
    let vict_out = vict.wait_with_output()?;
    anyhow::ensure!(vict_out.status.success(),
                    "phase-1 victim exited abnormally");
    println!("P{victim} died after round {DIE_AFTER}; label is degraded");
    // Let the label run degraded for a few ~80 ms (WAN-paced) rounds
    // before the comeback; the remaining rounds take ~900 ms (plus the
    // survivor's straggler window), so the rejoin lands mid-run with
    // margin on both sides even under a slow process spawn.
    std::thread::sleep(Duration::from_millis(250));
    // The victim's latest boundary snapshot: DIE_AFTER completed
    // rounds (it died before completing its in-flight round).
    let ckpt = dir.join(format!(
        "ckpt_p{victim:03}_round_{DIE_AFTER:08}.celuckpt"));
    anyhow::ensure!(ckpt.is_file(),
                    "expected snapshot {} missing", ckpt.display());
    let back = Command::new(&exe)
        .args(["--role", "rejoin", "--party", &victim.to_string(),
               "--connect", addr.as_str(),
               "--resume", &ckpt.to_string_lossy()])
        .stdout(Stdio::piped())
        .spawn()?;

    let mut got = LinkMap::new();
    let surv_out = surv.wait_with_output()?;
    anyhow::ensure!(surv_out.status.success(), "survivor failed");
    parse_link_lines(&String::from_utf8_lossy(&surv_out.stdout), &mut got)?;
    let back_out = back.wait_with_output()?;
    anyhow::ensure!(back_out.status.success(), "rejoined victim failed");
    let back_text = String::from_utf8_lossy(&back_out.stdout).into_owned();
    parse_link_lines(&back_text, &mut got)?;
    let restored = grab_line(&back_text, "RESTORED ")?;
    anyhow::ensure!(restored == DIE_AFTER,
                    "snapshot restored round {restored}, expected \
                     {DIE_AFTER}");
    let resume = grab_line(&back_text, "RESUMED ")?;
    let replays = back_text
        .lines()
        .find_map(|l| l.strip_prefix("RESUMED "))
        .and_then(|rest| rest.split_whitespace().nth(1)?.parse().ok())
        .unwrap_or(u64::MAX);

    let mut rest = String::new();
    std::io::Read::read_to_string(&mut label_out, &mut rest)?;
    anyhow::ensure!(label.wait()?.success(), "label process failed");
    parse_link_lines(&rest, &mut got)?;
    let rounds = grab_line(&rest, "ROUNDS ")?;
    let stale = grab_line(&rest, "STALE ")?;
    let rejoins = grab_line(&rest, "REJOINS ")?;
    let events: Vec<(String, i64, u64)> = rest
        .lines()
        .filter_map(|l| l.strip_prefix("EVENT "))
        .map(|rest| {
            let mut it = rest.split_whitespace();
            (it.next().unwrap_or("").to_string(),
             it.next().and_then(|v| v.parse().ok()).unwrap_or(-2),
             it.next().and_then(|v| v.parse().ok()).unwrap_or(0))
        })
        .collect();

    // ---- the acceptance assertions ----------------------------------------
    println!("\nchaos outcome: rounds={rounds} stale={stale} \
              rejoins={rejoins} resume={resume} replays={replays}");
    for e in &events {
        println!("  event {} party={} round={}", e.0, e.1, e.2);
    }
    // 1. Same final round count as the undisturbed reference.
    anyhow::ensure!(rounds == ROUNDS,
                    "label finished {rounds} rounds, reference {ROUNDS}");
    anyhow::ensure!(resume > DIE_AFTER && resume < ROUNDS,
                    "rejoin landed outside the run (resume {resume})");
    anyhow::ensure!(replays == 1,
                    "the in-flight round-{DIE_AFTER} derivative must be \
                     replayed exactly once (got {replays})");
    anyhow::ensure!(stale >= 2,
                    "expected ≥2 degraded rounds (victim outage + \
                     survivor straggle), saw {stale}");
    anyhow::ensure!(rejoins == 1, "expected exactly one rejoin");
    // 2. Lifecycle events recorded.
    let has = |kind: &str, party: i64| {
        events.iter().any(|(k, p, _)| k == kind && *p == party)
    };
    anyhow::ensure!(has("peer_lost", victim as i64),
                    "no peer_lost for P{victim}");
    anyhow::ensure!(has("peer_rejoined", victim as i64),
                    "no peer_rejoined for P{victim}");
    anyhow::ensure!(has("straggler_timeout", survivor as i64),
                    "no straggler_timeout for P{survivor}");
    // 3. The survivor's links are byte-identical to the undisturbed
    //    reference: stragglers reconcile, they do not change the wire.
    for key in [(survivor, 0u16), (0u16, survivor)] {
        anyhow::ensure!(
            got.get(&key) == expected.get(&key),
            "survivor link {key:?} diverged from the reference: \
             {:?} != {:?}", got.get(&key), expected.get(&key)
        );
    }
    // 4. The victim's accounting is training-only and frame-exact. All
    //    frames on a lane have fixed sizes, so every row must be an
    //    exact multiple — bootstrap/rejoin handshakes live on raw
    //    sockets and must not leak a byte into LinkStats.
    let (act_w, act_r) = frame_cost(victim_codec, victim)?;
    let der = (act_w, act_r); // same shape, same per-lane codec
    let shutdown = shutdown_cost();
    let post = got[&(victim, 0)];
    anyhow::ensure!(
        post == ((ROUNDS - resume) * act_w, (ROUNDS - resume) * act_r,
                 ROUNDS - resume),
        "rejoined P{victim} row {post:?} != {} acts of {act_w}/{act_r} B",
        ROUNDS - resume
    );
    let l_row = got[&(0, victim)];
    // Sends while the lane was up: rounds 0..DIE_AFTER for sure, the
    // death-round send races the EOF (counted iff the kernel took it),
    // then resume..ROUNDS after the rejoin, +1 replay, +1 Shutdown.
    let base = DIE_AFTER + (ROUNDS - resume) + 1;
    let fits = |m: u64| l_row == (m * der.0 + shutdown,
                                  m * der.1 + shutdown, m + 1);
    anyhow::ensure!(
        fits(base) || fits(base + 1),
        "label→P{victim} row {l_row:?} is not training-frame-exact \
         (base {base}, der {}/{} B, shutdown {shutdown} B)",
        der.0, der.1
    );
    let _ = std::fs::remove_dir_all(&dir);
    println!(
        "\nK=3 chaos (kill feature P{victim}, codec {}) OK: \
         snapshot-resume converged to {ROUNDS} rounds; P{survivor} \
         byte-identical to reference; P{victim} accounting frame-exact",
        victim_codec.label()
    );
    Ok(())
}

/// `--kill label`: crash the label at a boundary, relaunch it with
/// `--resume`, and assert every post-restart link segment is
/// byte-identical, per round, to the reference.
fn orchestrate_label_kill() -> anyhow::Result<()> {
    use std::process::{Command, Stdio};
    let expected = run_inproc_reference()?;
    println!("in-proc reference complete ({} links)", expected.len());

    let dir = std::env::temp_dir().join(format!(
        "celu_chaos_label_{}", std::process::id()));
    let _ = std::fs::remove_dir_all(&dir);
    let dir_s = dir.to_string_lossy().into_owned();

    let exe = std::env::current_exe()?;
    let mut label = Command::new(&exe)
        .args(["--role", "label", "--listen", "127.0.0.1:0",
               "--die-after", &KILL_LABEL_AFTER.to_string(),
               "--ckpt-dir", &dir_s])
        .stdout(Stdio::piped())
        .spawn()?;
    let mut label_out =
        std::io::BufReader::new(label.stdout.take().expect("label stdout"));
    let addr = read_addr(&mut label_out)?;
    println!("label listening at {addr}; spawning resilient features");

    let spawn_feature = |party: u16| {
        Command::new(&exe)
            .args(["--role", "feature-resilient",
                   "--party", &party.to_string(),
                   "--connect", addr.as_str()])
            .stdout(Stdio::piped())
            .spawn()
    };
    let p1 = spawn_feature(1)?;
    let p2 = spawn_feature(2)?;

    // Phase 1 ends when the label reaches its crash point: it prints
    // the snapshot path and hard-exits.
    let mut first = String::new();
    std::io::Read::read_to_string(&mut label_out, &mut first)?;
    anyhow::ensure!(label.wait()?.success(), "phase-1 label failed");
    let ckpt = first
        .lines()
        .find_map(|l| l.trim().strip_prefix("CKPT "))
        .ok_or_else(|| anyhow::anyhow!("no CKPT line from the label"))?
        .to_string();
    let resume_round = KILL_LABEL_AFTER + 1;
    anyhow::ensure!(
        ckpt.contains(&format!("ckpt_round_{resume_round:08}")),
        "unexpected snapshot path {ckpt}"
    );
    println!("label died after round {KILL_LABEL_AFTER}; relaunching \
              from {ckpt}");
    let relaunch = Command::new(&exe)
        .args(["--role", "label", "--listen", addr.as_str(),
               "--resume", &ckpt])
        .stdout(Stdio::piped())
        .spawn()?;

    let mut got = LinkMap::new();
    let mut resumes = Vec::new();
    for (party, proc_) in [(1u16, p1), (2u16, p2)] {
        let out = proc_.wait_with_output()?;
        anyhow::ensure!(out.status.success(), "P{party} failed");
        let text = String::from_utf8_lossy(&out.stdout).into_owned();
        parse_link_lines(&text, &mut got)?;
        resumes.push((party, grab_line(&text, "RESUMED ")?));
    }
    let relaunch_out = relaunch.wait_with_output()?;
    anyhow::ensure!(relaunch_out.status.success(),
                    "relaunched label failed");
    let text = String::from_utf8_lossy(&relaunch_out.stdout).into_owned();
    parse_link_lines(&text, &mut got)?;
    let rounds = grab_line(&text, "ROUNDS ")?;

    // ---- the acceptance assertions ----------------------------------------
    println!("\nchaos outcome: rounds={rounds} resumes={resumes:?}");
    // 1. Round-count parity: the relaunched label completed the run.
    anyhow::ensure!(rounds == ROUNDS,
                    "relaunched label finished {rounds} rounds, \
                     reference {ROUNDS}");
    // 2. Both features resumed exactly at the snapshot round.
    for (party, resume) in &resumes {
        anyhow::ensure!(
            *resume == resume_round,
            "P{party} resumed at {resume}, snapshot says {resume_round}"
        );
    }
    // 3. Every post-restart link segment is byte-identical, per round,
    //    to the reference: frames have fixed per-lane sizes, so the
    //    reference totals divide evenly and scale to the surviving
    //    segment exactly.
    let remaining = ROUNDS - resume_round;
    let shutdown = shutdown_cost();
    for p in [1u16, 2] {
        let full = expected[&(p, 0)];
        anyhow::ensure!(
            full.2 == ROUNDS && full.0 % ROUNDS == 0
                && full.1 % ROUNDS == 0,
            "reference P{p} row not per-round divisible: {full:?}"
        );
        let want = (full.0 / ROUNDS * remaining,
                    full.1 / ROUNDS * remaining, remaining);
        anyhow::ensure!(
            got[&(p, 0)] == want,
            "post-restart P{p}→label segment {:?} != {want:?}",
            got[&(p, 0)]
        );
        let full = expected[&(0, p)];
        anyhow::ensure!(
            full.2 == ROUNDS + 1
                && (full.0 - shutdown) % ROUNDS == 0
                && (full.1 - shutdown) % ROUNDS == 0,
            "reference label→P{p} row not per-round divisible: {full:?}"
        );
        let want = ((full.0 - shutdown) / ROUNDS * remaining + shutdown,
                    (full.1 - shutdown) / ROUNDS * remaining + shutdown,
                    remaining + 1);
        anyhow::ensure!(
            got[&(0, p)] == want,
            "post-restart label→P{p} segment {:?} != {want:?}",
            got[&(0, p)]
        );
    }
    let _ = std::fs::remove_dir_all(&dir);
    println!(
        "\nK=3 chaos (kill label) OK: snapshot-relaunch converged to \
         {ROUNDS} rounds; every post-restart link segment \
         byte-identical to the reference"
    );
    Ok(())
}

fn main() -> anyhow::Result<()> {
    celu_vfl::util::logger::init();
    let cli = Cli::new("chaos_k3",
                       "K=3 kill-any-role chaos matrix (three OS \
                        processes)")
        .opt("role", "orchestrate",
             "orchestrate | label | feature | feature-resilient | rejoin")
        .opt("kill", "feature2",
             "orchestrate: which role to kill (label | feature1 | \
              feature2)")
        .opt("listen", "127.0.0.1:0", "label: listener bind address")
        .opt("connect", "127.0.0.1:0", "feature: label party address")
        .opt("party", "1", "feature: party id (1 or 2)")
        .opt("die-after", "-",
             "feature: exit after this round's send; label: snapshot \
              and exit after this round's fan-out")
        .opt("straggle-round", "-",
             "feature: sleep through this round's send")
        .opt("ckpt-dir", "-", "write boundary snapshots to this dir")
        .opt("last-round", "0", "rejoin: rounds completed before death")
        .opt("resume", "-",
             "label/rejoin: restart from this snapshot file");
    let argv: Vec<String> = std::env::args().skip(1).collect();
    let args = cli.parse(&argv)?;
    let opt_u64 = |key: &str| -> anyhow::Result<Option<u64>> {
        match args.get(key) {
            "-" => Ok(None),
            v => Ok(Some(v.parse::<u64>().map_err(|e| {
                anyhow::anyhow!("bad --{key} '{v}': {e}")
            })?)),
        }
    };
    let opt_str = |key: &str| -> Option<String> {
        match args.get(key) {
            "-" => None,
            v => Some(v.to_string()),
        }
    };
    match args.get("role") {
        "orchestrate" => match args.get("kill") {
            "label" => orchestrate_label_kill(),
            "feature1" => orchestrate_feature_kill(1),
            "feature2" => orchestrate_feature_kill(2),
            other => anyhow::bail!(
                "--kill must be label | feature1 | feature2, got \
                 '{other}'"),
        },
        "label" => run_label(
            args.get("listen"),
            opt_u64("die-after")?,
            opt_str("ckpt-dir").as_deref(),
            opt_str("resume").as_deref(),
        ),
        "feature" => run_feature(
            args.get_usize("party")? as u16,
            args.get("connect"),
            opt_u64("die-after")?,
            opt_u64("straggle-round")?,
            opt_str("ckpt-dir").as_deref(),
        ),
        "feature-resilient" => run_feature_resilient(
            args.get_usize("party")? as u16,
            args.get("connect"),
        ),
        "rejoin" => run_rejoiner(
            args.get_usize("party")? as u16,
            args.get("connect"),
            args.get_u64("last-round")?,
            opt_str("resume").as_deref(),
        ),
        other => anyhow::bail!("unknown role '{other}'"),
    }
}
