//! K = 3 chaos smoke — kill a feature party mid-run, Rejoin it, finish.
//!
//! The CI proof of the supervised session lifecycle (DESIGN.md §8):
//! run with no arguments, this binary re-executes itself as three OS
//! processes over loopback TCP — a supervised label party (bounded
//! straggler waits + a live re-admission point) and two feature
//! dialers. Mid-run:
//!
//! - feature party 2 **exits** right after sending its round-3
//!   activation (its in-flight round) — the label party observes the
//!   dead lane, emits `PeerLost`, and keeps stepping on P2's cached
//!   stale statistics;
//! - the orchestrator relaunches P2 in **rejoin mode**: it re-dials
//!   with `Rejoin{last_round: 3}`, receives the buffered round-3
//!   derivative as a replay, fast-forwards to the acked resume round
//!   and finishes the run in lock-step;
//! - feature party 1 sleeps through one round (straggler): the label
//!   party emits `StragglerTimeout`, steps on P1's stale statistics,
//!   and reconciles when the late activation arrives — P1's wire
//!   traffic is **byte-identical** to the undisturbed in-proc
//!   reference, which the orchestrator asserts per link.
//!
//! The run must complete the same number of rounds as the undisturbed
//! reference, with `peer_lost`/`peer_rejoined`/`straggler_timeout`
//! events recorded, and with training-only byte accounting intact:
//! every per-link row must be an exact multiple of its frame size
//! (the bootstrap/rejoin handshakes live on raw sockets and never
//! leak into `LinkStats`).
//!
//!     cargo run --release --example chaos_k3

use std::io::BufRead;
use std::sync::Arc;
use std::time::Duration;

use celu_vfl::compress::{self, CodecKind};
use celu_vfl::config::{RunConfig, WanProfile};
use celu_vfl::protocol::{outbound_stats, Lane, Message,
                         FRAME_V2_OVERHEAD};
use celu_vfl::session::bootstrap::{inproc_mesh, rejoin_dial,
                                   SessionDialer, SessionListener};
use celu_vfl::session::supervisor::{session_epoch, LaneSet};
use celu_vfl::session::{Link, PartyId, LABEL_PARTY};
use celu_vfl::tensor::Tensor;
use celu_vfl::transport::Transport;
use celu_vfl::util::cli::Cli;

const ROUNDS: u64 = 14;
const BATCH: usize = 16;
const Z_DIM: usize = 4;
const STRAGGLER_MS: u64 = 250;
/// P2's in-flight round when it dies.
const DIE_AFTER: u64 = 3;
/// P1 sleeps through this round to force a straggler timeout.
const STRAGGLE_ROUND: u64 = 8;
const JOIN_TIMEOUT: Duration = Duration::from_secs(20);

/// The session under test: K=3, supervised; party 1 compresses fp16
/// while party 2 stays uncompressed, so the run also covers join-time
/// codec pre-negotiation (no Hello frames anywhere) and mixed per-link
/// codecs under degradation.
///
/// The simulated WAN matters here: degraded rounds are paced by the
/// *live* lanes, so with instant links the label would finish every
/// remaining round in microseconds and the relaunched P2 would find a
/// dead listener. An 80 ms RTT (~40 ms per send, charged identically
/// in the in-proc reference, so byte parity is unaffected) makes each
/// round take ~80 ms — the rejoin deterministically lands mid-run.
fn smoke_cfg() -> RunConfig {
    let mut cfg = RunConfig::quick();
    cfg.parties = 3;
    cfg.wan = WanProfile { bandwidth_mbps: 0.0, rtt_ms: 80.0,
                           gateway_ms: 0.0 };
    cfg.compress = CodecKind::Identity;
    cfg.party_compress = vec![(1, CodecKind::Fp16)];
    cfg.straggler_wait_ms = STRAGGLER_MS;
    cfg.validate().expect("smoke config invalid");
    cfg
}

/// Deterministic stand-in for a bottom model's activations — identical
/// in every process and in the in-proc reference run.
fn synth(party: u16, round: u64) -> Tensor {
    let v: Vec<f32> = (0..BATCH * Z_DIM)
        .map(|i| {
            ((i as f32 * 0.31 + party as f32 * 1.7 + round as f32 * 0.13)
                .sin())
                * 0.8
        })
        .collect();
    Tensor::f32(vec![BATCH, Z_DIM], v)
}

/// One feature party's traffic from `start` to ROUNDS. The codec is
/// pre-negotiated from the link's join-time mask — no Hello. `die`
/// exits the process right after sending that round's activation;
/// `straggle` sleeps past the label's wait window before sending.
fn feature_rounds(party: PartyId, transport: &Arc<dyn Transport>,
                  codec: CodecKind, start: u64, die: Option<u64>,
                  straggle: Option<u64>) -> anyhow::Result<()> {
    for round in start..ROUNDS {
        if straggle == Some(round) {
            std::thread::sleep(Duration::from_millis(STRAGGLER_MS + 200));
        }
        let za = synth(party.0, round);
        let (msg, _za) = outbound_stats(codec, Lane::Activation, round, za)?;
        transport.send(msg)?;
        if die == Some(round) {
            // Hard exit mid-round: the in-flight activation is on the
            // wire, the derivative never gets consumed.
            std::process::exit(0);
        }
        match transport.recv()?.into_plain()? {
            Message::Derivative { round: r, .. } => {
                anyhow::ensure!(r == round, "round skew on {party}: \
                                             got {r}, at {round}");
            }
            other => anyhow::bail!("unexpected {:?}", other.tag()),
        }
    }
    match transport.recv()? {
        Message::Shutdown => Ok(()),
        other => anyhow::bail!("expected Shutdown, got {:?}", other.tag()),
    }
}

fn negotiated(cfg: &RunConfig, party: PartyId, link: &Link) -> CodecKind {
    compress::negotiate(cfg.codec_for(party.0), link.peer_codecs)
}

/// The supervised label loop over a [`LaneSet`] — the same machinery
/// `coordinator::label_party` drives, minus the model.
fn label_rounds(cfg: &RunConfig, lanes: &mut LaneSet)
                -> anyhow::Result<(u64, u64)> {
    lanes.handshake(cfg, None)?;
    let mut stale_rounds = 0u64;
    for round in 0..ROUNDS {
        let inputs = lanes.collect(round)?;
        if inputs.iter().any(|i| !i.is_fresh()) {
            stale_rounds += 1;
        }
        let zs: Vec<Tensor> = inputs
            .iter()
            .filter_map(|i| i.tensor().cloned())
            .collect();
        let zsum = Tensor::sum_f32(&zs)?;
        // Stand-in for the exact step: ∇Z = 0.1 · ΣZ.
        let dza = Tensor::f32(
            zsum.shape.clone(),
            zsum.as_f32()?.iter().map(|x| 0.1 * x).collect::<Vec<_>>(),
        );
        let _views = lanes.stage_derivatives(round, &dza)?;
        lanes.send_staged(round)?;
    }
    lanes.shutdown();
    Ok((ROUNDS, stale_rounds))
}

fn link_line(src: u16, dst: u16,
             s: &celu_vfl::transport::LinkStats) -> String {
    format!("LINK {src} {dst} {} {} {}", s.bytes, s.raw_bytes, s.messages)
}

// ---- the three roles -------------------------------------------------------

fn run_label(listen: &str) -> anyhow::Result<()> {
    let cfg = smoke_cfg();
    let listener = SessionListener::bind(listen)?.with_timeout(JOIN_TIMEOUT);
    println!("ADDR {}", listener.local_addr()?);
    use std::io::Write;
    std::io::stdout().flush()?;
    let (links, readmission, _epoch, _start) =
        listener.establish_supervised(&cfg)?;
    let mut lanes = LaneSet::new(&cfg, &links, Some(readmission));
    let (rounds, stale_rounds) = label_rounds(&cfg, &mut lanes)?;
    println!("ROUNDS {rounds}");
    println!("STALE {stale_rounds}");
    println!("REJOINS {}", lanes.total_rejoins());
    for e in lanes.take_events() {
        println!(
            "EVENT {} {} {}",
            e.kind(),
            e.party().map(|p| p.0 as i64).unwrap_or(-1),
            e.round()
        );
    }
    for (peer, s) in lanes.link_stats() {
        println!("{}", link_line(LABEL_PARTY.0, peer.0, &s));
    }
    Ok(())
}

fn run_feature(party: u16, connect: &str, die: Option<u64>,
               straggle: Option<u64>) -> anyhow::Result<()> {
    let cfg = smoke_cfg();
    let (link, start) = SessionDialer::new(connect, PartyId(party))
        .with_timeout(JOIN_TIMEOUT)
        .establish_resumable(&cfg)?;
    anyhow::ensure!(start == 0, "fresh join resumed at {start}");
    let codec = negotiated(&cfg, PartyId(party), &link);
    feature_rounds(PartyId(party), &link.transport, codec, 0, die,
                   straggle)?;
    println!("{}", link_line(party, LABEL_PARTY.0,
                             &link.transport.stats()));
    Ok(())
}

/// Relaunched P2: re-dial with `Rejoin`, consume the replayed
/// in-flight derivative, resume at the acked round.
fn run_rejoiner(party: u16, connect: &str, last_round: u64)
                -> anyhow::Result<()> {
    let cfg = smoke_cfg();
    let epoch = session_epoch(cfg.seed);
    let (transport, resume, replays) = rejoin_dial(
        connect, PartyId(party), &cfg, epoch, last_round, JOIN_TIMEOUT)?;
    for _ in 0..replays {
        match transport.recv()?.into_plain()? {
            Message::Derivative { round: r, .. } => {
                anyhow::ensure!(
                    r == last_round,
                    "replay carries round {r}, expected {last_round}"
                );
            }
            other => anyhow::bail!("unexpected replay {:?}", other.tag()),
        }
    }
    // Same build ⇒ the label decodes everything we do; see
    // SessionDialer::establish_resumable for the mask rationale.
    let codec = compress::negotiate(cfg.codec_for(party),
                                    Some(compress::supported_mask()));
    let transport = &transport;
    feature_rounds(PartyId(party), transport, codec, resume, None, None)?;
    println!("RESUMED {resume} {replays}");
    println!("{}", link_line(party, LABEL_PARTY.0, &transport.stats()));
    Ok(())
}

// ---- undisturbed reference -------------------------------------------------

type LinkMap = std::collections::BTreeMap<(u16, u16), (u64, u64, u64)>;

fn parse_link_lines(text: &str, into: &mut LinkMap) -> anyhow::Result<()> {
    for line in text.lines() {
        let Some(rest) = line.trim().strip_prefix("LINK ") else {
            continue;
        };
        let f: Vec<u64> = rest
            .split_whitespace()
            .map(|x| x.parse::<u64>())
            .collect::<Result<_, _>>()
            .map_err(|e| anyhow::anyhow!("bad LINK line '{line}': {e}"))?;
        anyhow::ensure!(f.len() == 5, "bad LINK line '{line}'");
        let prev = into.insert((f[0] as u16, f[1] as u16),
                               (f[2], f[3], f[4]));
        anyhow::ensure!(prev.is_none(),
                        "duplicate LINK row {}→{}", f[0], f[1]);
    }
    Ok(())
}

/// Undisturbed reference over the in-proc bootstrap: same LaneSet, no
/// kill, no straggler.
fn run_inproc_reference() -> anyhow::Result<LinkMap> {
    let cfg = smoke_cfg();
    let (label_bs, feature_bs) = inproc_mesh(&cfg);
    let mut handles = Vec::new();
    let mut feature_transports = Vec::new();
    let mut label_links: Vec<Link> = Vec::new();
    for (i, bs) in feature_bs.into_iter().enumerate() {
        let party = PartyId(i as u16 + 1);
        let cfg_f = cfg.clone();
        let link = {
            use celu_vfl::session::bootstrap::MeshBootstrap;
            bs.establish(&cfg)?.swap_remove(0)
        };
        let codec = negotiated(&cfg_f, party, &link);
        let transport = link.transport.clone();
        feature_transports.push((party, transport.clone()));
        handles.push(std::thread::spawn(move || {
            feature_rounds(party, &transport, codec, 0, None, None)
        }));
    }
    {
        use celu_vfl::session::bootstrap::MeshBootstrap;
        label_links.extend(label_bs.establish(&cfg)?);
    }
    let mut lanes = LaneSet::new(&cfg, &label_links, None);
    let (rounds, stale) = label_rounds(&cfg, &mut lanes)?;
    anyhow::ensure!(rounds == ROUNDS && stale == 0,
                    "reference run degraded ({rounds} rounds, {stale} \
                     stale)");
    for h in handles {
        h.join().expect("feature thread panicked")?;
    }
    let mut map = LinkMap::new();
    for (peer, s) in lanes.link_stats() {
        map.insert((LABEL_PARTY.0, peer.0),
                   (s.bytes, s.raw_bytes, s.messages));
    }
    for (party, t) in feature_transports {
        let s = t.stats();
        map.insert((party.0, LABEL_PARTY.0),
                   (s.bytes, s.raw_bytes, s.messages));
    }
    Ok(map)
}

// ---- orchestrator ----------------------------------------------------------

fn orchestrate() -> anyhow::Result<()> {
    use std::process::{Command, Stdio};

    let expected = run_inproc_reference()?;
    println!("in-proc reference complete ({} links)", expected.len());

    let exe = std::env::current_exe()?;
    let mut label = Command::new(&exe)
        .args(["--role", "label", "--listen", "127.0.0.1:0"])
        .stdout(Stdio::piped())
        .spawn()?;
    let mut label_out =
        std::io::BufReader::new(label.stdout.take().expect("label stdout"));
    let mut addr = String::new();
    loop {
        let mut line = String::new();
        anyhow::ensure!(
            label_out.read_line(&mut line)? > 0,
            "label process exited before announcing its address"
        );
        if let Some(a) = line.trim().strip_prefix("ADDR ") {
            addr = a.to_string();
            break;
        }
    }
    println!("label listening at {addr}; spawning feature processes");

    // P1: full run, with one deliberate straggle. P2: dies after its
    // round-DIE_AFTER activation.
    let p1 = Command::new(&exe)
        .args(["--role", "feature", "--party", "1",
               "--connect", addr.as_str(),
               "--straggle-round", &STRAGGLE_ROUND.to_string()])
        .stdout(Stdio::piped())
        .spawn()?;
    let p2 = Command::new(&exe)
        .args(["--role", "feature", "--party", "2",
               "--connect", addr.as_str(),
               "--die-after", &DIE_AFTER.to_string()])
        .stdout(Stdio::piped())
        .spawn()?;
    let p2_out = p2.wait_with_output()?;
    anyhow::ensure!(p2_out.status.success(),
                    "phase-1 P2 exited abnormally");
    println!("P2 died after round {DIE_AFTER}; label is degraded");
    // Let the label run degraded for a few ~80 ms (WAN-paced) rounds
    // before the comeback; the remaining 11 rounds take ~900 ms (plus
    // P1's straggler window), so the rejoin lands mid-run with margin
    // on both sides even under a slow process spawn.
    std::thread::sleep(Duration::from_millis(250));
    let p2b = Command::new(&exe)
        .args(["--role", "rejoin", "--party", "2",
               "--connect", addr.as_str(),
               "--last-round", &DIE_AFTER.to_string()])
        .stdout(Stdio::piped())
        .spawn()?;

    let mut got = LinkMap::new();
    let p1_out = p1.wait_with_output()?;
    anyhow::ensure!(p1_out.status.success(), "P1 failed");
    parse_link_lines(&String::from_utf8_lossy(&p1_out.stdout), &mut got)?;
    let p2b_out = p2b.wait_with_output()?;
    anyhow::ensure!(p2b_out.status.success(), "rejoined P2 failed");
    let p2b_text = String::from_utf8_lossy(&p2b_out.stdout).into_owned();
    parse_link_lines(&p2b_text, &mut got)?;
    let (resume, replays) = p2b_text
        .lines()
        .find_map(|l| l.strip_prefix("RESUMED "))
        .and_then(|rest| {
            let mut it = rest.split_whitespace();
            Some((it.next()?.parse::<u64>().ok()?,
                  it.next()?.parse::<u64>().ok()?))
        })
        .ok_or_else(|| anyhow::anyhow!("no RESUMED line from P2"))?;

    let mut rest = String::new();
    std::io::Read::read_to_string(&mut label_out, &mut rest)?;
    anyhow::ensure!(label.wait()?.success(), "label process failed");
    parse_link_lines(&rest, &mut got)?;
    let grab = |prefix: &str| -> anyhow::Result<u64> {
        rest.lines()
            .find_map(|l| l.strip_prefix(prefix))
            .and_then(|v| v.trim().parse::<u64>().ok())
            .ok_or_else(|| anyhow::anyhow!("no {prefix} line from label"))
    };
    let rounds = grab("ROUNDS ")?;
    let stale = grab("STALE ")?;
    let rejoins = grab("REJOINS ")?;
    let events: Vec<(String, i64, u64)> = rest
        .lines()
        .filter_map(|l| l.strip_prefix("EVENT "))
        .map(|rest| {
            let mut it = rest.split_whitespace();
            (it.next().unwrap_or("").to_string(),
             it.next().and_then(|v| v.parse().ok()).unwrap_or(-2),
             it.next().and_then(|v| v.parse().ok()).unwrap_or(0))
        })
        .collect();

    // ---- the acceptance assertions ----------------------------------------
    println!("\nchaos outcome: rounds={rounds} stale={stale} \
              rejoins={rejoins} resume={resume} replays={replays}");
    for e in &events {
        println!("  event {} party={} round={}", e.0, e.1, e.2);
    }
    // 1. Same final round count as the undisturbed reference.
    anyhow::ensure!(rounds == ROUNDS,
                    "label finished {rounds} rounds, reference {ROUNDS}");
    anyhow::ensure!(resume > DIE_AFTER && resume < ROUNDS,
                    "rejoin landed outside the run (resume {resume})");
    anyhow::ensure!(replays == 1,
                    "the in-flight round-{DIE_AFTER} derivative must be \
                     replayed exactly once (got {replays})");
    anyhow::ensure!(stale >= 2,
                    "expected ≥2 degraded rounds (P2 outage + P1 \
                     straggle), saw {stale}");
    anyhow::ensure!(rejoins == 1, "expected exactly one rejoin");
    // 2. Lifecycle events recorded.
    let has = |kind: &str, party: i64| {
        events.iter().any(|(k, p, _)| k == kind && *p == party)
    };
    anyhow::ensure!(has("peer_lost", 2), "no peer_lost for P2");
    anyhow::ensure!(has("peer_rejoined", 2), "no peer_rejoined for P2");
    anyhow::ensure!(has("straggler_timeout", 1),
                    "no straggler_timeout for P1");
    // 3. P1's links are byte-identical to the undisturbed reference:
    //    stragglers reconcile, they do not change the wire.
    for key in [(1u16, 0u16), (0u16, 1u16)] {
        anyhow::ensure!(
            got.get(&key) == expected.get(&key),
            "P1 link {key:?} diverged from the reference: {:?} != {:?}",
            got.get(&key), expected.get(&key)
        );
    }
    // 4. P2's accounting is training-only and frame-exact. All frames
    //    on the identity lane have fixed sizes, so every row must be an
    //    exact multiple — the rejoin handshake ran on the raw socket
    //    and must not have leaked a byte into LinkStats.
    let act = (Message::Activation { round: 0, tensor: synth(2, 0) }
        .wire_bytes() + FRAME_V2_OVERHEAD) as u64;
    let der = act; // same shape, same identity codec
    let shutdown =
        (Message::Shutdown.wire_bytes() + FRAME_V2_OVERHEAD) as u64;
    let p2_row = got[&(2, 0)];
    anyhow::ensure!(
        p2_row == ((ROUNDS - resume) * act, (ROUNDS - resume) * act,
                   ROUNDS - resume),
        "rejoined P2 row {:?} != {} acts of {act} B", p2_row,
        ROUNDS - resume
    );
    let l2_row = got[&(0, 2)];
    // Sends while the lane was up: rounds 0..DIE_AFTER for sure, the
    // death-round send races the EOF (counted iff the kernel took it),
    // then resume..ROUNDS after the rejoin, +1 replay, +1 Shutdown.
    let base = DIE_AFTER + (ROUNDS - resume) + 1;
    let candidates = [
        (base * der + shutdown, base + 1),
        ((base + 1) * der + shutdown, base + 2),
    ];
    anyhow::ensure!(
        candidates.iter().any(|&(b, m)| l2_row == (b, b, m)),
        "label→P2 row {:?} is not training-frame-exact (base {base}, \
         der {der} B, shutdown {shutdown} B)", l2_row
    );
    println!(
        "\nK=3 chaos smoke OK: kill+Rejoin mid-round converged to \
         {ROUNDS} rounds; P1 byte-identical to reference; P2 \
         accounting frame-exact"
    );
    Ok(())
}

fn main() -> anyhow::Result<()> {
    celu_vfl::util::logger::init();
    let cli = Cli::new("chaos_k3",
                       "K=3 kill+Rejoin chaos smoke (three OS processes)")
        .opt("role", "orchestrate",
             "orchestrate | label | feature | rejoin")
        .opt("listen", "127.0.0.1:0", "label: listener bind address")
        .opt("connect", "127.0.0.1:0", "feature: label party address")
        .opt("party", "1", "feature: party id (1 or 2)")
        .opt("die-after", "-", "feature: exit after this round's send")
        .opt("straggle-round", "-",
             "feature: sleep through this round's send")
        .opt("last-round", "0", "rejoin: rounds completed before death");
    let argv: Vec<String> = std::env::args().skip(1).collect();
    let args = cli.parse(&argv)?;
    let opt_u64 = |key: &str| -> anyhow::Result<Option<u64>> {
        match args.get(key) {
            "-" => Ok(None),
            v => Ok(Some(v.parse::<u64>().map_err(|e| {
                anyhow::anyhow!("bad --{key} '{v}': {e}")
            })?)),
        }
    };
    match args.get("role") {
        "orchestrate" => orchestrate(),
        "label" => run_label(args.get("listen")),
        "feature" => run_feature(
            args.get_usize("party")? as u16,
            args.get("connect"),
            opt_u64("die-after")?,
            opt_u64("straggle-round")?,
        ),
        "rejoin" => run_rejoiner(
            args.get_usize("party")? as u16,
            args.get("connect"),
            args.get_u64("last-round")?,
        ),
        other => anyhow::bail!("unknown role '{other}'"),
    }
}
