//! Chaos-campaign smoke (DESIGN.md §13) — the per-PR slice of the
//! nightly sweep, artifact-free.
//!
//! Runs a small fixed campaign (two mesh scenario families × two
//! seeded cases) twice and asserts:
//!
//! - every case passes its oracles (round parity, clean-link byte
//!   identity, no hang within the budget);
//! - the two runs produce **byte-identical** JSON reports — the
//!   reproducibility contract that makes a nightly failure
//!   re-derivable from `(root_seed, scenario, index)` alone;
//! - the shrinker minimizes a synthetically-failing plan to its known
//!   1-minimal reproducer and renders it as a paste-ready
//!   `FaultPlan` builder chain.
//!
//! Exits non-zero on any drift.

use std::time::Duration;

use celu_vfl::campaign::{
    run_campaign, shrink_case, CampaignOpts, CasePlan, FaultOp,
    LinkFault, Scenario,
};

fn main() -> anyhow::Result<()> {
    celu_vfl::util::logger::init();

    let opts = CampaignOpts {
        scenarios: vec![Scenario::Single, Scenario::Reorder],
        seeds: 2,
        root_seed: 42,
        budget: Duration::from_secs(60),
        shrink: false,
    };
    let first = run_campaign(&opts);
    anyhow::ensure!(
        first.failed() == 0,
        "campaign smoke found failures:\n{}",
        first.failure_details()
    );
    anyhow::ensure!(first.cases.len() == 4, "expected 4 cases, ran {}",
                    first.cases.len());
    let injected: u64 = first
        .cases
        .iter()
        .map(|c| c.outcome.faults_injected)
        .sum();
    anyhow::ensure!(injected >= 4,
                    "every case must inject at least once, saw \
                     {injected} total");

    let second = run_campaign(&opts);
    let (a, b) = (first.to_json().to_string(),
                  second.to_json().to_string());
    anyhow::ensure!(a == b,
                    "the same root seed produced different reports");

    // The shrinker's contract on a known synthetic failure: only a
    // DropFrame at index >= 2 with >= 3 rounds matters; the rest of
    // the fat plan must be stripped.
    let fat = CasePlan {
        scenario: Scenario::Single,
        root_seed: 42,
        index: 0,
        case_seed: 0xFEED,
        parties: 3,
        rounds: 8,
        codecs: Vec::new(),
        faults: vec![
            LinkFault {
                party: 1,
                ops: vec![FaultOp::DelayMs(1, 60),
                          FaultOp::DropFrame(6)],
            },
            LinkFault { party: 2,
                        ops: vec![FaultOp::ReorderFrames(3)] },
        ],
    };
    let fails = |p: &CasePlan| {
        p.rounds >= 3
            && p.faults.iter().any(|f| {
                f.ops.iter().any(
                    |op| matches!(op, FaultOp::DropFrame(n) if *n >= 2))
            })
    };
    let shrunk = shrink_case(&fat, fails);
    anyhow::ensure!(shrunk.plan.rounds == 3
                        && shrunk.plan.faults.len() == 1
                        && shrunk.plan.faults[0].ops
                            == vec![FaultOp::DropFrame(2)],
                    "shrinker left a non-minimal plan: {:?}",
                    shrunk.plan);
    let chain = shrunk.plan.faults[0].builder_chain(
        shrunk.plan.case_seed);
    anyhow::ensure!(chain.ends_with(".drop_frame(2)"),
                    "unexpected builder chain: {chain}");

    println!("{}", first.summary_table());
    println!("campaign smoke OK: 2x{} cases byte-identical, shrink \
              reproducer `{chain}` ({} evals)",
             first.cases.len(), shrunk.evals);
    Ok(())
}
