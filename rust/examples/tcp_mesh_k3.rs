//! K = 3 TCP session smoke — three OS processes over loopback.
//!
//! The CI proof that the listener-based bootstrap (DESIGN.md §7)
//! launches the topology the paper targets: run with no arguments,
//! this binary re-executes itself as **three separate OS processes** —
//! one label-party session server (`--role label`) and two feature
//! dialers (`--role feature --party N`) — joined over loopback TCP via
//! the `Join`/`JoinAck` handshake. Each process drives the same
//! deterministic protocol-level traffic as `mesh_k3` (v2 frames,
//! per-link `Hello` negotiation with a per-party codec override,
//! Σ_k Z_k aggregation) without the PJRT runtime, then reports its
//! per-link sender-side byte accounting. The orchestrator runs the
//! identical traffic over the in-proc mesh and asserts the per-link
//! totals — wire bytes, raw bytes, message counts — are **identical**:
//! the bootstrap handshake lives on the raw socket, outside the
//! transports, so a TCP session costs exactly what the simulated-WAN
//! mesh charges.
//!
//!     cargo run --release --example tcp_mesh_k3            # orchestrate
//!     cargo run --release --example tcp_mesh_k3 -- --role label --listen 127.0.0.1:0
//!     cargo run --release --example tcp_mesh_k3 -- --role feature --party 1 --connect 127.0.0.1:PORT

use std::collections::BTreeMap;
use std::io::BufRead;
use std::sync::Arc;
use std::time::Duration;

use celu_vfl::compress::{self, CodecKind};
use celu_vfl::config::{RunConfig, WanProfile};
use celu_vfl::protocol::{outbound_stats, Lane, Message};
use celu_vfl::session::bootstrap::{inproc_mesh, SessionDialer,
                                   SessionListener};
use celu_vfl::session::{PartyId, Session, SessionBuilder, LABEL_PARTY};
use celu_vfl::tensor::Tensor;
use celu_vfl::transport::Transport;
use celu_vfl::util::cli::Cli;

const ROUNDS: u64 = 8;
const BATCH: usize = 16;
const Z_DIM: usize = 4;
const JOIN_TIMEOUT: Duration = Duration::from_secs(20);

/// The session under test: K=3, party 1 compresses fp16 while party 2
/// stays uncompressed, so the byte parity covers the `Hello` handshake
/// and mixed per-link codecs, not just plain tensor frames.
fn smoke_cfg() -> RunConfig {
    let mut cfg = RunConfig::quick();
    cfg.parties = 3;
    cfg.wan = WanProfile::instant();
    cfg.compress = CodecKind::Identity;
    cfg.party_compress = vec![(1, CodecKind::Fp16)];
    cfg.validate().expect("smoke config invalid");
    cfg
}

/// Deterministic stand-in for a bottom model's activations — identical
/// in every process and in the in-proc reference run.
fn synth(party: u16, round: u64) -> Tensor {
    let v: Vec<f32> = (0..BATCH * Z_DIM)
        .map(|i| {
            ((i as f32 * 0.31 + party as f32 * 1.7 + round as f32 * 0.13)
                .sin())
                * 0.8
        })
        .collect();
    Tensor::f32(vec![BATCH, Z_DIM], v)
}

/// One feature party's traffic: optional `Hello` handshake, then
/// ROUNDS of Activation → Derivative, then the label's Shutdown.
fn feature_loop(party: PartyId, transport: &Arc<dyn Transport>,
                requested: CodecKind) -> anyhow::Result<()> {
    let codec = if requested != CodecKind::Identity {
        transport.send(Message::Hello {
            codecs: compress::supported_mask(),
        })?;
        match transport.recv()? {
            Message::Hello { codecs } => {
                compress::negotiate(requested, Some(codecs))
            }
            other => anyhow::bail!("expected Hello, got {:?}", other.tag()),
        }
    } else {
        CodecKind::Identity
    };
    for round in 0..ROUNDS {
        let za = synth(party.0, round);
        let (msg, _za) = outbound_stats(codec, Lane::Activation, round, za)?;
        transport.send(msg)?;
        match transport.recv()?.into_plain()? {
            Message::Derivative { round: r, .. } => {
                anyhow::ensure!(r == round, "round skew on {party}");
            }
            other => anyhow::bail!("unexpected {:?}", other.tag()),
        }
    }
    match transport.recv()? {
        Message::Shutdown => Ok(()),
        other => anyhow::bail!("expected Shutdown, got {:?}", other.tag()),
    }
}

/// The label party's traffic over its whole mesh.
fn label_loop(cfg: &RunConfig, session: &Session) -> anyhow::Result<()> {
    let mut lanes = Vec::new();
    for l in session.mesh().links() {
        let requested = cfg.codec_for(l.peer.0);
        let mut replay = None;
        let codec = match l.transport.recv()? {
            Message::Hello { codecs } => {
                l.transport.send(Message::Hello {
                    codecs: compress::supported_mask(),
                })?;
                compress::negotiate(requested, Some(codecs))
            }
            first => {
                replay = Some(first);
                CodecKind::Identity
            }
        };
        lanes.push((l.peer, l.transport.clone(), codec, replay));
    }
    for round in 0..ROUNDS {
        let mut zas = Vec::with_capacity(lanes.len());
        for (peer, transport, _, replay) in lanes.iter_mut() {
            let msg = match replay.take() {
                Some(m) => m,
                None => transport.recv()?,
            };
            match msg.into_plain()? {
                Message::Activation { round: r, tensor } => {
                    anyhow::ensure!(r == round, "skew on {peer}");
                    zas.push(tensor);
                }
                other => anyhow::bail!("unexpected {:?}", other.tag()),
            }
        }
        let zsum = Tensor::sum_f32(&zas)?;
        // Stand-in for the exact step: ∇Z = 0.1 · ΣZ.
        let dza = Tensor::f32(
            zsum.shape.clone(),
            zsum.as_f32()?.iter().map(|x| 0.1 * x).collect::<Vec<_>>(),
        );
        for (_, transport, codec, _) in lanes.iter() {
            let (dmsg, _) = outbound_stats(*codec, Lane::Derivative,
                                           round, dza.clone())?;
            transport.send(dmsg)?;
        }
    }
    for (_, transport, _, _) in &lanes {
        transport.send(Message::Shutdown)?;
    }
    Ok(())
}

/// Sender-side per-link rows: (src, dst) → (wire, raw, msgs).
type LinkMap = BTreeMap<(u16, u16), (u64, u64, u64)>;

fn link_line(src: u16, dst: u16,
             s: &celu_vfl::transport::LinkStats) -> String {
    format!("LINK {src} {dst} {} {} {}", s.bytes, s.raw_bytes, s.messages)
}

fn parse_link_lines(text: &str, into: &mut LinkMap) -> anyhow::Result<()> {
    for line in text.lines() {
        let Some(rest) = line.trim().strip_prefix("LINK ") else {
            continue;
        };
        let f: Vec<u64> = rest
            .split_whitespace()
            .map(|x| x.parse::<u64>())
            .collect::<Result<_, _>>()
            .map_err(|e| anyhow::anyhow!("bad LINK line '{line}': {e}"))?;
        anyhow::ensure!(f.len() == 5, "bad LINK line '{line}'");
        let prev = into.insert((f[0] as u16, f[1] as u16),
                               (f[2], f[3], f[4]));
        anyhow::ensure!(prev.is_none(),
                        "duplicate LINK row {}→{}", f[0], f[1]);
    }
    Ok(())
}

// ---- the three roles -------------------------------------------------------

fn run_label(listen: &str) -> anyhow::Result<()> {
    let cfg = smoke_cfg();
    let listener = SessionListener::bind(listen)?.with_timeout(JOIN_TIMEOUT);
    // The orchestrator reads this line to learn the bound port (the
    // listener is started on port 0 to dodge port races in CI).
    println!("ADDR {}", listener.local_addr()?);
    use std::io::Write;
    std::io::stdout().flush()?;
    let session = SessionBuilder::from_bootstrap(&cfg, listener)?;
    label_loop(&cfg, &session)?;
    for (peer, s) in session.mesh().link_stats() {
        println!("{}", link_line(LABEL_PARTY.0, peer.0, &s));
    }
    Ok(())
}

fn run_feature(party: u16, connect: &str) -> anyhow::Result<()> {
    let cfg = smoke_cfg();
    let session = SessionBuilder::from_bootstrap(
        &cfg,
        SessionDialer::new(connect, PartyId(party))
            .with_timeout(JOIN_TIMEOUT),
    )?;
    let transport = session.mesh().links()[0].transport.clone();
    feature_loop(PartyId(party), &transport, cfg.codec_for(party))?;
    println!("{}", link_line(party, LABEL_PARTY.0, &transport.stats()));
    Ok(())
}

/// Reference run: identical traffic over the in-proc bootstrap.
fn run_inproc_reference() -> anyhow::Result<LinkMap> {
    let cfg = smoke_cfg();
    let (label_bs, feature_bs) = inproc_mesh(&cfg);
    let label_session = SessionBuilder::from_bootstrap(&cfg, label_bs)?;
    let mut handles = Vec::new();
    let mut feature_transports = Vec::new();
    for (i, bs) in feature_bs.into_iter().enumerate() {
        let party = PartyId(i as u16 + 1);
        let cfg_f = cfg.clone();
        let session = SessionBuilder::from_bootstrap(&cfg, bs)?;
        let transport = session.mesh().links()[0].transport.clone();
        feature_transports.push((party, transport.clone()));
        handles.push(std::thread::spawn(move || {
            feature_loop(party, &transport, cfg_f.codec_for(party.0))
        }));
    }
    label_loop(&cfg, &label_session)?;
    for h in handles {
        h.join().expect("feature thread panicked")?;
    }
    let mut map = LinkMap::new();
    for (peer, s) in label_session.mesh().link_stats() {
        map.insert((LABEL_PARTY.0, peer.0),
                   (s.bytes, s.raw_bytes, s.messages));
    }
    for (party, t) in feature_transports {
        let s = t.stats();
        map.insert((party.0, LABEL_PARTY.0),
                   (s.bytes, s.raw_bytes, s.messages));
    }
    Ok(map)
}

// ---- orchestrator ----------------------------------------------------------

fn orchestrate() -> anyhow::Result<()> {
    use std::process::{Command, Stdio};

    let expected = run_inproc_reference()?;
    println!("in-proc reference complete ({} links)", expected.len());

    let exe = std::env::current_exe()?;
    let mut label = Command::new(&exe)
        .args(["--role", "label", "--listen", "127.0.0.1:0"])
        .stdout(Stdio::piped())
        .spawn()?;
    let mut label_out =
        std::io::BufReader::new(label.stdout.take().expect("label stdout"));
    let mut addr = String::new();
    loop {
        let mut line = String::new();
        anyhow::ensure!(
            label_out.read_line(&mut line)? > 0,
            "label process exited before announcing its address"
        );
        if let Some(a) = line.trim().strip_prefix("ADDR ") {
            addr = a.to_string();
            break;
        }
    }
    println!("label listening at {addr}; spawning feature processes");

    let features: Vec<_> = [1u16, 2]
        .iter()
        .map(|p| {
            let party = p.to_string();
            Command::new(&exe)
                .args(["--role", "feature", "--party", party.as_str(),
                       "--connect", addr.as_str()])
                .stdout(Stdio::piped())
                .spawn()
        })
        .collect::<Result<_, _>>()?;

    let mut got = LinkMap::new();
    for (i, f) in features.into_iter().enumerate() {
        let out = f.wait_with_output()?;
        anyhow::ensure!(out.status.success(),
                        "feature process {} failed", i + 1);
        parse_link_lines(&String::from_utf8_lossy(&out.stdout), &mut got)?;
    }
    let mut rest = String::new();
    std::io::Read::read_to_string(&mut label_out, &mut rest)?;
    anyhow::ensure!(label.wait()?.success(), "label process failed");
    parse_link_lines(&rest, &mut got)?;

    // ---- the acceptance assertion ----------------------------------------
    println!("\n{:<8} {:>12} {:>12} {:>6}   (tcp == in-proc?)",
             "link", "wire B", "raw B", "msgs");
    for (&(src, dst), &(bytes, raw, msgs)) in &expected {
        let tcp = got.get(&(src, dst));
        println!("{src}->{dst:<5} {bytes:>12} {raw:>12} {msgs:>6}   {}",
                 if tcp == Some(&(bytes, raw, msgs)) { "OK" }
                 else { "MISMATCH" });
    }
    anyhow::ensure!(
        got == expected,
        "per-link byte accounting diverged between the TCP session and \
         the in-proc mesh:\n  tcp:     {got:?}\n  in-proc: {expected:?}"
    );
    // Sanity: the fp16 link (party 1) beat the identity link (party 2)
    // on wire bytes in both worlds.
    let fp16 = got[&(0, 1)].0;
    let ident = got[&(0, 2)].0;
    anyhow::ensure!(fp16 < ident,
                    "fp16 link ({fp16} B) not smaller than identity \
                     link ({ident} B)");
    println!(
        "\nK=3 TCP smoke OK: 3 OS processes, {ROUNDS} rounds, {} links \
         byte-identical to the in-proc mesh",
        got.len()
    );
    Ok(())
}

fn main() -> anyhow::Result<()> {
    celu_vfl::util::logger::init();
    let cli = Cli::new("tcp_mesh_k3",
                       "K=3 TCP session smoke (three OS processes)")
        .opt("role", "orchestrate", "orchestrate | label | feature")
        .opt("listen", "127.0.0.1:0", "label: listener bind address")
        .opt("connect", "127.0.0.1:0", "feature: label party address")
        .opt("party", "1", "feature: party id (1 or 2)");
    let argv: Vec<String> = std::env::args().skip(1).collect();
    let args = cli.parse(&argv)?;
    match args.get("role") {
        "orchestrate" => orchestrate(),
        "label" => run_label(args.get("listen")),
        "feature" => run_feature(args.get_usize("party")? as u16,
                                 args.get("connect")),
        other => anyhow::bail!("unknown role '{other}'"),
    }
}
