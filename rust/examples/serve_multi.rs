//! Multi-session service-plane smoke — one server process, two
//! concurrent K = 3 sessions, five OS processes total.
//!
//! The CI proof of DESIGN.md §11's acceptance bar: a single
//! `SessionServer` process hosts **two independent K=3 sessions to
//! completion**, and every session's per-link wire/raw byte accounting
//! is **identical** to an isolated single-session run of the same
//! traffic. Run with no arguments, this binary re-executes itself as
//! one server (hosting both sessions behind one port) plus four
//! feature dialers (two per session, addressed by seed). Because two
//! same-sized meshes assemble concurrently, a plain `Join` cannot be
//! routed by content — every dialer exercises the full fallback:
//! `Join` → `RejoinReject{NeedRejoin}` → epoch-bearing `Rejoin`
//! routed exactly by seed-derived session epoch. The handshake lives
//! on the raw socket, outside the transports, so multiplexed sessions
//! must cost byte-for-byte what isolated ones cost — that is the
//! assertion.
//!
//!     cargo run --release --example serve_multi           # orchestrate
//!     cargo run --release --example serve_multi -- --role server
//!     cargo run --release --example serve_multi -- --role feature \
//!         --party 1 --seed 7 --connect 127.0.0.1:PORT

use std::collections::BTreeMap;
use std::io::BufRead;
use std::sync::Arc;
use std::time::Duration;

use celu_vfl::compress::{self, CodecKind};
use celu_vfl::config::{RunConfig, WanProfile};
use celu_vfl::protocol::{outbound_stats, Lane, Message};
use celu_vfl::session::bootstrap::SessionDialer;
use celu_vfl::session::server::{SessionHandle, SessionServer};
use celu_vfl::session::{inproc_star, Link, PartyId, LABEL_PARTY};
use celu_vfl::tensor::Tensor;
use celu_vfl::transport::Transport;
use celu_vfl::util::cli::Cli;

const ROUNDS: u64 = 8;
const BATCH: usize = 16;
const Z_DIM: usize = 4;
const SEEDS: [u64; 2] = [7, 11];
const JOIN_TIMEOUT: Duration = Duration::from_secs(20);

/// One hosted session's config: K=3, mixed per-link codecs (party 1
/// fp16, party 2 identity) so parity covers the `Hello` handshake, and
/// a per-session seed that derives the routing epoch AND varies the
/// synthetic tensors — the two sessions must not be byte-identical to
/// *each other* for the parity check to mean anything.
fn smoke_cfg(seed: u64) -> RunConfig {
    let mut cfg = RunConfig::quick();
    cfg.parties = 3;
    cfg.seed = seed;
    cfg.wan = WanProfile::instant();
    cfg.compress = CodecKind::Identity;
    cfg.party_compress = vec![(1, CodecKind::Fp16)];
    cfg.validate().expect("smoke config invalid");
    cfg
}

/// Deterministic activations, distinct per (seed, party, round).
fn synth(seed: u64, party: u16, round: u64) -> Tensor {
    let v: Vec<f32> = (0..BATCH * Z_DIM)
        .map(|i| {
            ((i as f32 * 0.31 + party as f32 * 1.7
              + round as f32 * 0.13 + seed as f32 * 0.57)
                .sin())
                * 0.8
        })
        .collect();
    Tensor::f32(vec![BATCH, Z_DIM], v)
}

/// One feature party's traffic (same protocol as `tcp_mesh_k3`).
fn feature_loop(seed: u64, party: PartyId,
                transport: &Arc<dyn Transport>, requested: CodecKind)
                -> anyhow::Result<()> {
    let codec = if requested != CodecKind::Identity {
        transport.send(Message::Hello {
            codecs: compress::supported_mask(),
        })?;
        match transport.recv()? {
            Message::Hello { codecs } => {
                compress::negotiate(requested, Some(codecs))
            }
            other => anyhow::bail!("expected Hello, got {:?}", other.tag()),
        }
    } else {
        CodecKind::Identity
    };
    for round in 0..ROUNDS {
        let za = synth(seed, party.0, round);
        let (msg, _za) = outbound_stats(codec, Lane::Activation, round, za)?;
        transport.send(msg)?;
        match transport.recv()?.into_plain()? {
            Message::Derivative { round: r, .. } => {
                anyhow::ensure!(r == round, "round skew on {party}");
            }
            other => anyhow::bail!("unexpected {:?}", other.tag()),
        }
    }
    match transport.recv()? {
        Message::Shutdown => Ok(()),
        other => anyhow::bail!("expected Shutdown, got {:?}", other.tag()),
    }
}

/// The label side of one session's traffic, over its mesh links.
fn label_loop(cfg: &RunConfig, links: &[Link]) -> anyhow::Result<()> {
    let mut lanes = Vec::new();
    for l in links {
        let requested = cfg.codec_for(l.peer.0);
        let mut replay = None;
        let codec = match l.transport.recv()? {
            Message::Hello { codecs } => {
                l.transport.send(Message::Hello {
                    codecs: compress::supported_mask(),
                })?;
                compress::negotiate(requested, Some(codecs))
            }
            first => {
                replay = Some(first);
                CodecKind::Identity
            }
        };
        lanes.push((l.peer, l.transport.clone(), codec, replay));
    }
    for round in 0..ROUNDS {
        let mut zas = Vec::with_capacity(lanes.len());
        for (peer, transport, _, replay) in lanes.iter_mut() {
            let msg = match replay.take() {
                Some(m) => m,
                None => transport.recv()?,
            };
            match msg.into_plain()? {
                Message::Activation { round: r, tensor } => {
                    anyhow::ensure!(r == round, "skew on {peer}");
                    zas.push(tensor);
                }
                other => anyhow::bail!("unexpected {:?}", other.tag()),
            }
        }
        let zsum = Tensor::sum_f32(&zas)?;
        let dza = Tensor::f32(
            zsum.shape.clone(),
            zsum.as_f32()?.iter().map(|x| 0.1 * x).collect::<Vec<_>>(),
        );
        for (_, transport, codec, _) in lanes.iter() {
            let (dmsg, _) = outbound_stats(*codec, Lane::Derivative,
                                           round, dza.clone())?;
            transport.send(dmsg)?;
        }
    }
    for (_, transport, _, _) in &lanes {
        transport.send(Message::Shutdown)?;
    }
    Ok(())
}

/// Per-link rows keyed by (seed, src, dst) → (wire, raw, msgs).
type LinkMap = BTreeMap<(u64, u16, u16), (u64, u64, u64)>;

fn link_line(seed: u64, src: u16, dst: u16,
             s: &celu_vfl::transport::LinkStats) -> String {
    format!("LINK {seed} {src} {dst} {} {} {}",
            s.bytes, s.raw_bytes, s.messages)
}

fn parse_link_lines(text: &str, into: &mut LinkMap) -> anyhow::Result<()> {
    for line in text.lines() {
        let Some(rest) = line.trim().strip_prefix("LINK ") else {
            continue;
        };
        let f: Vec<u64> = rest
            .split_whitespace()
            .map(|x| x.parse::<u64>())
            .collect::<Result<_, _>>()
            .map_err(|e| anyhow::anyhow!("bad LINK line '{line}': {e}"))?;
        anyhow::ensure!(f.len() == 6, "bad LINK line '{line}'");
        let prev = into.insert((f[0], f[1] as u16, f[2] as u16),
                               (f[3], f[4], f[5]));
        anyhow::ensure!(prev.is_none(),
                        "duplicate LINK row s{} {}→{}", f[0], f[1], f[2]);
    }
    Ok(())
}

// ---- the roles -------------------------------------------------------------

/// The one server process: both sessions behind one port, each driven
/// by the protocol-level label loop on its own runner thread.
fn run_server(listen: &str) -> anyhow::Result<()> {
    let mut server = SessionServer::bind(listen)?
        .with_join_timeout(JOIN_TIMEOUT);
    for seed in SEEDS {
        server.host(smoke_cfg(seed))?;
    }
    println!("ADDR {}", server.local_addr()?);
    use std::io::Write;
    std::io::stdout().flush()?;
    let runner = |h: SessionHandle| -> anyhow::Result<()> {
        label_loop(&h.cfg, &h.links)?;
        let mut out = String::new();
        for l in &h.links {
            out.push_str(&link_line(h.cfg.seed, LABEL_PARTY.0, l.peer.0,
                                    &l.transport.stats()));
            out.push('\n');
        }
        // One write per session so concurrent runners can't interleave
        // mid-line.
        print!("{out}");
        Ok(())
    };
    let outcomes = server.serve(runner)?;
    for o in &outcomes {
        if let Err(e) = &o.result {
            anyhow::bail!("session {} failed: {e:#}", o.label);
        }
    }
    println!("SERVED {}", outcomes.len());
    Ok(())
}

fn run_feature(seed: u64, party: u16, connect: &str) -> anyhow::Result<()> {
    let cfg = smoke_cfg(seed);
    // establish_resumable, not plain establish: with two assembling
    // sessions the server refuses content-routed Joins, and the dialer
    // must fall back to the epoch-bearing Rejoin.
    let (link, start) = SessionDialer::new(connect, PartyId(party))
        .with_timeout(JOIN_TIMEOUT)
        .establish_resumable(&cfg)?;
    anyhow::ensure!(start == 0, "fresh dial resumed at round {start}");
    feature_loop(seed, PartyId(party), &link.transport,
                 cfg.codec_for(party))?;
    println!("{}", link_line(seed, party, LABEL_PARTY.0,
                             &link.transport.stats()));
    Ok(())
}

/// Isolated reference for one seed: identical traffic over the in-proc
/// star — what a single-session run of this mesh costs.
fn run_inproc_reference(seed: u64) -> anyhow::Result<LinkMap> {
    let cfg = smoke_cfg(seed);
    let (label_links, feature_links) = inproc_star(&cfg);
    let mut handles = Vec::new();
    let mut feature_transports = Vec::new();
    for (i, l) in feature_links.into_iter().enumerate() {
        let party = PartyId(i as u16 + 1);
        let transport = l.transport.clone();
        let requested = cfg.codec_for(party.0);
        feature_transports.push((party, transport.clone()));
        handles.push(std::thread::spawn(move || {
            feature_loop(seed, party, &transport, requested)
        }));
    }
    label_loop(&cfg, &label_links)?;
    for h in handles {
        h.join().expect("feature thread panicked")?;
    }
    let mut map = LinkMap::new();
    for l in &label_links {
        let s = l.transport.stats();
        map.insert((seed, LABEL_PARTY.0, l.peer.0),
                   (s.bytes, s.raw_bytes, s.messages));
    }
    for (party, t) in feature_transports {
        let s = t.stats();
        map.insert((seed, party.0, LABEL_PARTY.0),
                   (s.bytes, s.raw_bytes, s.messages));
    }
    Ok(map)
}

// ---- orchestrator ----------------------------------------------------------

fn orchestrate() -> anyhow::Result<()> {
    use std::process::{Command, Stdio};

    let mut expected = LinkMap::new();
    for seed in SEEDS {
        expected.extend(run_inproc_reference(seed)?);
    }
    println!("isolated references complete ({} links across {} sessions)",
             expected.len(), SEEDS.len());

    let exe = std::env::current_exe()?;
    let mut server = Command::new(&exe)
        .args(["--role", "server", "--listen", "127.0.0.1:0"])
        .stdout(Stdio::piped())
        .spawn()?;
    let mut server_out = std::io::BufReader::new(
        server.stdout.take().expect("server stdout"));
    let mut addr = String::new();
    loop {
        let mut line = String::new();
        anyhow::ensure!(
            server_out.read_line(&mut line)? > 0,
            "server process exited before announcing its address"
        );
        if let Some(a) = line.trim().strip_prefix("ADDR ") {
            addr = a.to_string();
            break;
        }
    }
    println!("server at {addr}; spawning 4 feature processes \
              (2 sessions x 2 parties, interleaved)");

    // Interleave the two sessions' dialers so both meshes assemble
    // concurrently — the scenario single-tenant listeners cannot serve.
    let features: Vec<_> = [(SEEDS[0], 1u16), (SEEDS[1], 1),
                            (SEEDS[0], 2), (SEEDS[1], 2)]
        .iter()
        .map(|&(seed, p)| {
            Command::new(&exe)
                .args(["--role", "feature",
                       "--party", &p.to_string(),
                       "--seed", &seed.to_string(),
                       "--connect", addr.as_str()])
                .stdout(Stdio::piped())
                .spawn()
        })
        .collect::<Result<_, _>>()?;

    let mut got = LinkMap::new();
    for (i, f) in features.into_iter().enumerate() {
        let out = f.wait_with_output()?;
        anyhow::ensure!(out.status.success(),
                        "feature process {} failed", i + 1);
        parse_link_lines(&String::from_utf8_lossy(&out.stdout), &mut got)?;
    }
    let mut rest = String::new();
    std::io::Read::read_to_string(&mut server_out, &mut rest)?;
    anyhow::ensure!(server.wait()?.success(), "server process failed");
    anyhow::ensure!(rest.contains(&format!("SERVED {}", SEEDS.len())),
                    "server did not report both sessions complete");
    parse_link_lines(&rest, &mut got)?;

    // ---- the acceptance assertion ----------------------------------------
    println!("\n{:<14} {:>12} {:>12} {:>6}   (multiplexed == isolated?)",
             "session/link", "wire B", "raw B", "msgs");
    for (&(seed, src, dst), &(bytes, raw, msgs)) in &expected {
        let tcp = got.get(&(seed, src, dst));
        println!("s{seed} {src}->{dst:<7} {bytes:>12} {raw:>12} \
                  {msgs:>6}   {}",
                 if tcp == Some(&(bytes, raw, msgs)) { "OK" }
                 else { "MISMATCH" });
    }
    anyhow::ensure!(
        got == expected,
        "per-link byte accounting diverged between the multiplexed \
         server and isolated runs:\n  server:   {got:?}\n  isolated: \
         {expected:?}"
    );
    // The two sessions carried different traffic (different seeds), so
    // matching totals are not a coincidence of symmetry.
    anyhow::ensure!(
        got[&(SEEDS[0], 0, 2)] != got[&(SEEDS[1], 0, 2)],
        "sessions produced identical bytes — parity check is vacuous"
    );
    println!(
        "\nmulti-session smoke OK: 1 server process, {} concurrent K=3 \
         sessions, {} links byte-identical to isolated runs",
        SEEDS.len(), got.len()
    );
    Ok(())
}

fn main() -> anyhow::Result<()> {
    celu_vfl::util::logger::init();
    let cli = Cli::new("serve_multi",
                       "multi-session server smoke (five OS processes)")
        .opt("role", "orchestrate", "orchestrate | server | feature")
        .opt("listen", "127.0.0.1:0", "server: bind address")
        .opt("connect", "127.0.0.1:0", "feature: server address")
        .opt("party", "1", "feature: party id (1 or 2)")
        .opt("seed", "7", "feature: session seed (selects the session)");
    let argv: Vec<String> = std::env::args().skip(1).collect();
    let args = cli.parse(&argv)?;
    match args.get("role") {
        "orchestrate" => orchestrate(),
        "server" => run_server(args.get("listen")),
        "feature" => run_feature(args.get_u64("seed")?,
                                 args.get_usize("party")? as u16,
                                 args.get("connect")),
        other => anyhow::bail!("unknown role '{other}'"),
    }
}
