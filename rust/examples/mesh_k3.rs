//! K = 3 session smoke run — artifact-free, a few rounds.
//!
//! Drives the full session plumbing (star mesh, v2 party-addressed
//! frames, per-link `Hello` negotiation with a per-party codec
//! override, K activation lanes, Σ_k Z_k aggregation, per-peer workset
//! lanes with round-robin local sampling, per-link byte accounting)
//! **without** the PJRT runtime: the model compute is replaced by a
//! deterministic statistics generator, so this runs on any checkout —
//! it is the CI smoke step for the session layer. The full-model K=3
//! run lives in `tests/integration.rs` behind the artifact gate.
//!
//!     cargo run --release --example mesh_k3

use celu_vfl::compress::{self, CodecKind};
use celu_vfl::config::{RunConfig, WanProfile};
use celu_vfl::protocol::{outbound_stats, Lane, Message,
                         FRAME_V2_OVERHEAD};
use celu_vfl::session::{inproc_star, PartyId, SessionBuilder,
                        LABEL_PARTY};
use celu_vfl::tensor::Tensor;
use celu_vfl::transport::Transport;
use celu_vfl::workset::MeshWorkset;

const ROUNDS: u64 = 8;
const BATCH: usize = 16;
const Z_DIM: usize = 4;

/// Deterministic stand-in for a bottom model's activations.
fn synth(party: u16, round: u64) -> Tensor {
    let v: Vec<f32> = (0..BATCH * Z_DIM)
        .map(|i| {
            ((i as f32 * 0.31 + party as f32 * 1.7 + round as f32 * 0.13)
                .sin())
                * 0.8
        })
        .collect();
    Tensor::f32(vec![BATCH, Z_DIM], v)
}

fn main() -> anyhow::Result<()> {
    celu_vfl::util::logger::init();
    let mut cfg = RunConfig::quick();
    cfg.parties = 3;
    cfg.wan = WanProfile::instant();
    // Per-party codec override: party 1 compresses fp16, party 2 stays
    // uncompressed — the links must negotiate independently.
    cfg.compress = CodecKind::Identity;
    cfg.party_compress = vec![(1, CodecKind::Fp16)];
    cfg.validate()?;

    let (label_links, feature_links) = inproc_star(&cfg);

    // Validate the topology through the real session builder (the
    // drivers themselves need compiled artifacts, so past this point
    // the example drives the mesh at the protocol level).
    let mut b = SessionBuilder::new(&cfg, LABEL_PARTY);
    for l in &label_links {
        b = b.link(l.peer, l.transport.clone());
    }
    let label_session = b.build()?;
    println!("session: {} as {:?}, {} links", label_session.id(),
             label_session.role(), label_session.mesh().len());

    // ---- feature parties (threads) ----------------------------------------
    let mut handles = Vec::new();
    for (i, link) in feature_links.into_iter().enumerate() {
        let party = PartyId(i as u16 + 1);
        let requested = cfg.codec_for(party.0);
        let transport = link.transport.clone();
        handles.push(std::thread::spawn(move || -> anyhow::Result<u64> {
            let ws = MeshWorkset::new(
                1, 3, 2, celu_vfl::config::Sampling::RoundRobin);
            // Per-link handshake: only a compressing party speaks.
            let codec = if requested != CodecKind::Identity {
                transport.send(Message::Hello {
                    codecs: compress::supported_mask(),
                })?;
                match transport.recv()? {
                    Message::Hello { codecs } => {
                        compress::negotiate(requested, Some(codecs))
                    }
                    other => anyhow::bail!("expected Hello, got {:?}",
                                           other.tag()),
                }
            } else {
                CodecKind::Identity
            };
            let mut local = 0u64;
            for round in 0..ROUNDS {
                let za = synth(party.0, round);
                let (msg, za) =
                    outbound_stats(codec, Lane::Activation, round, za)?;
                transport.send(msg)?;
                let dza = match transport.recv()?.into_plain()? {
                    Message::Derivative { round: r, tensor } => {
                        anyhow::ensure!(r == round, "round skew");
                        tensor
                    }
                    other => anyhow::bail!("unexpected {:?}", other.tag()),
                };
                ws.insert(round, vec![0u32; BATCH], vec![(za, dza)]);
                // Local updates overlap the next round's exchange.
                while ws.sample()?.is_some() {
                    local += 1;
                }
            }
            match transport.recv()? {
                Message::Shutdown => Ok(local),
                other => anyhow::bail!("expected Shutdown, got {:?}",
                                       other.tag()),
            }
        }));
    }

    // ---- label party (this thread) ----------------------------------------
    let mesh = label_session.mesh();
    let workset = MeshWorkset::new(mesh.len(), 3, 2,
                                   celu_vfl::config::Sampling::RoundRobin);
    // Handshake per link: answer whoever initiates.
    let mut lanes = Vec::new();
    for l in mesh.links() {
        let requested = cfg.codec_for(l.peer.0);
        let mut replay = None;
        let codec = match l.transport.recv()? {
            Message::Hello { codecs } => {
                l.transport.send(Message::Hello {
                    codecs: compress::supported_mask(),
                })?;
                compress::negotiate(requested, Some(codecs))
            }
            first => {
                replay = Some(first);
                CodecKind::Identity
            }
        };
        lanes.push((l.peer, l.transport.clone(), codec, replay));
    }
    let mut label_local = 0u64;
    for round in 0..ROUNDS {
        let mut zas = Vec::with_capacity(lanes.len());
        for (peer, transport, _, replay) in lanes.iter_mut() {
            let msg = match replay.take() {
                Some(m) => m,
                None => transport.recv()?,
            };
            match msg.into_plain()? {
                Message::Activation { round: r, tensor } => {
                    anyhow::ensure!(r == round, "skew on {peer}");
                    zas.push(tensor);
                }
                other => anyhow::bail!("unexpected {:?}", other.tag()),
            }
        }
        let zsum = Tensor::sum_f32(&zas)?;
        // Stand-in for the exact step: ∇Z = 0.1 · ΣZ.
        let dza = Tensor::f32(
            zsum.shape.clone(),
            zsum.as_f32()?.iter().map(|x| 0.1 * x).collect::<Vec<_>>(),
        );
        let mut cached = Vec::with_capacity(lanes.len());
        let mut outgoing = Vec::with_capacity(lanes.len());
        for ((_, _, codec, _), za_k) in lanes.iter().zip(zas) {
            let (dmsg, dza_k) =
                outbound_stats(*codec, Lane::Derivative, round,
                               dza.clone())?;
            outgoing.push(dmsg);
            cached.push((za_k, dza_k));
        }
        workset.insert(round, vec![0u32; BATCH], cached);
        for ((_, transport, _, _), dmsg) in lanes.iter().zip(outgoing) {
            transport.send(dmsg)?;
        }
        while let Some(e) = workset.sample()? {
            anyhow::ensure!(e.za.shape == vec![BATCH, Z_DIM],
                            "aggregate shape drifted: {:?}", e.za.shape);
            label_local += 1;
        }
    }
    for (_, transport, _, _) in &lanes {
        transport.send(Message::Shutdown)?;
    }
    let mut feature_local = 0u64;
    for h in handles {
        feature_local += h.join().expect("feature thread panicked")?;
    }

    // ---- assertions + per-link report --------------------------------------
    println!("\n{:<8} {:>10} {:>10} {:>8} {:>8}", "link", "wire B",
             "raw B", "msgs", "ratio");
    let mut fp16_link_bytes = 0;
    let mut ident_link_bytes = 0;
    for (peer, stats) in mesh.link_stats() {
        println!("0->{:<5} {:>10} {:>10} {:>8} {:>8.2}", peer.0,
                 stats.bytes, stats.raw_bytes, stats.messages,
                 stats.compression_ratio());
        anyhow::ensure!(stats.messages >= ROUNDS,
                        "link 0->{peer} undercounted messages");
        // Every frame on a K>2 link carries the 6-byte v2 envelope; the
        // identity direction's raw == wire, so the envelope is visible
        // as raw > payload-only accounting would give. fp16 links beat
        // identity links on wire bytes.
        if peer == PartyId(1) {
            fp16_link_bytes = stats.bytes;
        } else {
            ident_link_bytes = stats.bytes;
        }
    }
    anyhow::ensure!(fp16_link_bytes < ident_link_bytes,
                    "fp16 link ({fp16_link_bytes} B) not smaller than \
                     identity link ({ident_link_bytes} B)");
    let total = mesh.total_stats();
    anyhow::ensure!(total.messages >= 2 * ROUNDS,
                    "mesh undercounted messages: {}", total.messages);
    // The envelope is charged: the identity link's per-derivative cost
    // is the v1 frame + FRAME_V2_OVERHEAD.
    let v1_der = Message::Derivative {
        round: 0,
        tensor: synth(0, 0),
    }
    .wire_bytes();
    anyhow::ensure!(
        ident_link_bytes as usize >= ROUNDS as usize
            * (v1_der + FRAME_V2_OVERHEAD),
        "v2 envelope missing from the byte accounting"
    );
    anyhow::ensure!(label_local > 0, "label party never sampled locally");
    anyhow::ensure!(feature_local > 0, "feature parties never sampled");
    println!(
        "\nK=3 smoke OK: {ROUNDS} rounds, {feature_local} feature local \
         samples, {label_local} label local samples (aggregated over \
         {} lanes), {} B total on the mesh",
        mesh.len(),
        total.bytes
    );
    Ok(())
}
