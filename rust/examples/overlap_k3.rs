//! K = 3 limited-overlap streaming smoke run — artifact-free.
//!
//! Exercises the data plane end to end (DESIGN.md §12) without the
//! PJRT runtime: a CSV fixture is generated on disk, every party
//! streams its own vertical slice of it in bounded windows
//! (`CsvSource` → `FeatureFeed`/`LabelFeed`), and an `AlignmentMap` at
//! `overlap = 0.3` splits each window into aligned rows (which drive
//! the Z/∇Z exchange over the in-proc star) and unaligned rows (which
//! feed self-supervised denoising batches that never touch a link).
//! The model compute is replaced by deterministic tensor arithmetic,
//! so this runs on any checkout — it is the CI smoke step for the
//! streaming + limited-overlap plane. The full-model path lives behind
//! the artifact gate in `tests/integration.rs`.
//!
//! Asserted invariants:
//! - all parties draw identical aligned batch schedules from the
//!   shared seed, without exchanging a byte of coordination;
//! - the aligned fraction of the streamed file matches `--overlap`,
//!   so wire traffic per file pass is proportional to the overlap;
//! - self-supervised updates happen (every feature party runs them)
//!   yet per-link message counts stay exactly 2·rounds + shutdown —
//!   zero wire traffic from unaligned rows;
//! - no party ever materializes more than one `chunk_rows` window.
//!
//!     cargo run --release --example overlap_k3

use std::io::Write as _;
use std::sync::Arc;

use celu_vfl::config::{DataFormat, RunConfig, WanProfile};
use celu_vfl::data::batcher::GatherScratch;
use celu_vfl::data::split_widths;
use celu_vfl::dataset::{corrupt_tokens, AlignmentMap, CsvSource,
                        DatasetSource, FeatureFeed, LabelFeed};
use celu_vfl::protocol::Message;
use celu_vfl::session::{inproc_star, SessionBuilder, LABEL_PARTY};
use celu_vfl::tensor::Tensor;
use celu_vfl::transport::Transport;
use celu_vfl::util::rng::Pcg;

const ROWS: usize = 1200;
const FIELDS_A: usize = 14; // avazu layout: Party-A columns first
const FIELDS_B: usize = 8;
const VOCAB: usize = 1000;
const BATCH: usize = 16;
const CHUNK_ROWS: usize = 256;
const SKIP_ROWS: usize = 32; // evaluation prefix every party reserves
const OVERLAP: f64 = 0.3;
const SSL_RATIO: usize = 2;

/// Deterministic CSV fixture: `key,label,f0,…,f21` rows.
fn write_fixture(path: &std::path::Path) -> anyhow::Result<()> {
    let mut f = std::io::BufWriter::new(std::fs::File::create(path)?);
    let fields = FIELDS_A + FIELDS_B;
    for i in 0..ROWS {
        write!(f, "user-{i},{}", (i * 13 + i / 7) % 2)?;
        for c in 0..fields {
            write!(f, ",c{c}v{}", (i * 31 + c * 7) % 23)?;
        }
        writeln!(f)?;
    }
    f.flush()?;
    Ok(())
}

/// Deterministic stand-in for a bottom model: fold a `[batch, F]` i32
/// gather into a small f32 activation.
fn fold_tokens(xa: &Tensor) -> anyhow::Result<Tensor> {
    let rows = xa.shape[0];
    let f = xa.shape[1];
    let ids = xa.as_i32()?;
    let z: Vec<f32> = (0..rows)
        .map(|r| {
            ids[r * f..(r + 1) * f]
                .iter()
                .map(|&t| (t as f32 / VOCAB as f32).sin())
                .sum::<f32>()
        })
        .collect();
    Ok(Tensor::f32(vec![rows, 1], z))
}

/// Replay the window protocol analytically (pure functions of the file
/// and seed): how many aligned batches does one pass of the file
/// support, and what fraction of streamed rows is aligned?
fn plan_one_pass(path: &std::path::Path, seed: u64)
                 -> anyhow::Result<(u64, f64)> {
    let mut src = CsvSource::open(path, FIELDS_A + FIELDS_B, VOCAB)?;
    let map = AlignmentMap::new(seed, OVERLAP);
    // Consume the evaluation prefix exactly as the feeds do.
    let mut skipped = 0usize;
    while skipped < SKIP_ROWS {
        let want = (SKIP_ROWS - skipped).min(CHUNK_ROWS);
        skipped += src.next_chunk(want)?
            .map_or(0, |c| c.rows());
    }
    let (mut rounds, mut aligned_rows, mut seen_rows) = (0u64, 0usize, 0usize);
    while let Some(chunk) = src.next_chunk(CHUNK_ROWS)? {
        let (aligned, _) = map.split(&chunk.keys);
        seen_rows += chunk.rows();
        if aligned.len() < BATCH {
            continue; // the feeds skip this window identically
        }
        aligned_rows += aligned.len();
        rounds += (aligned.len() / BATCH) as u64;
    }
    Ok((rounds, aligned_rows as f64 / seen_rows as f64))
}

fn main() -> anyhow::Result<()> {
    celu_vfl::util::logger::init();
    let dir = std::env::temp_dir();
    let path = dir.join(format!("overlap_k3_{}.csv", std::process::id()));
    write_fixture(&path)?;

    let mut cfg = RunConfig::quick();
    cfg.parties = 3;
    cfg.wan = WanProfile::instant();
    cfg.data = path.display().to_string();
    cfg.data_format = DataFormat::Csv;
    cfg.chunk_rows = CHUNK_ROWS;
    cfg.overlap = OVERLAP;
    cfg.ssl_ratio = SSL_RATIO;
    cfg.validate()?;
    let seed = cfg.seed;

    let (rounds, aligned_frac) = plan_one_pass(&path, seed)?;
    anyhow::ensure!(rounds >= 8, "fixture too small: {rounds} rounds");
    // Wire traffic is one Z/∇Z exchange per *aligned* batch, so the
    // comm volume a file pass generates is proportional to the overlap
    // fraction. Pin that proportionality before driving the mesh.
    anyhow::ensure!(
        (aligned_frac - OVERLAP).abs() < 0.08,
        "aligned fraction {aligned_frac:.3} drifted from overlap \
         {OVERLAP}"
    );

    let widths = split_widths(FIELDS_A, cfg.feature_parties())?;
    let (label_links, feature_links) = inproc_star(&cfg);
    let mut b = SessionBuilder::new(&cfg, LABEL_PARTY);
    for l in &label_links {
        b = b.link(l.peer, l.transport.clone());
    }
    let label_session = b.build()?;

    // ---- feature parties (threads) -----------------------------------------
    let mut handles = Vec::new();
    let mut col_start = 0usize;
    for (slot, link) in feature_links.into_iter().enumerate() {
        let cols = col_start..col_start + widths[slot];
        col_start = cols.end;
        let transport = link.transport.clone();
        let data = cfg.data.clone();
        handles.push(std::thread::spawn(
            move || -> anyhow::Result<(Vec<Vec<u32>>, u64)> {
                let src = Box::new(CsvSource::open(
                    std::path::Path::new(&data),
                    FIELDS_A + FIELDS_B, VOCAB)?);
                let mut feed = FeatureFeed::streaming(
                    src, cols, AlignmentMap::new(seed, OVERLAP), seed,
                    BATCH, CHUNK_ROWS, SKIP_ROWS)?;
                anyhow::ensure!(feed.has_ssl_pool(),
                                "overlap {OVERLAP} pooled no rows");
                let mut scratch = GatherScratch::default();
                let mut ssl_rng = Pcg::new(seed ^ slot as u64, 0x551);
                let mut schedule = Vec::new();
                let mut ssl_updates = 0u64;
                for round in 0..rounds {
                    let (idx, xa) = feed.batch(round, &mut scratch)?;
                    // The live window is the only materialized slice.
                    let (window, _) = feed.share().snapshot();
                    anyhow::ensure!(window.n <= CHUNK_ROWS,
                                    "window {} exceeds chunk bound",
                                    window.n);
                    schedule.push(idx);
                    transport.send(Message::Activation {
                        round, tensor: fold_tokens(&xa)?,
                    })?;
                    match transport.recv()?.into_plain()? {
                        Message::Derivative { round: r, .. } => {
                            anyhow::ensure!(r == round, "round skew")
                        }
                        other => anyhow::bail!("unexpected {:?}",
                                               other.tag()),
                    }
                    // Self-supervised work on unaligned rows: denoising
                    // pairs built and consumed locally — no link I/O.
                    for _ in 0..SSL_RATIO {
                        let Some(clean) = feed.ssl_batch(&mut scratch)
                        else { break };
                        let noisy = corrupt_tokens(
                            &clean, VOCAB, 0.15, &mut ssl_rng)?;
                        anyhow::ensure!(
                            noisy.shape == clean.shape,
                            "corrupt_tokens changed the batch shape");
                        ssl_updates += 1;
                    }
                }
                // Sender-side accounting: exactly one activation per
                // aligned batch left this endpoint — the SSL loop put
                // nothing on the wire.
                anyhow::ensure!(
                    transport.stats().messages == rounds,
                    "party {} sent {} messages for {rounds} aligned \
                     rounds", slot + 1, transport.stats().messages
                );
                match transport.recv()? {
                    Message::Shutdown => Ok((schedule, ssl_updates)),
                    other => anyhow::bail!("expected Shutdown, got {:?}",
                                           other.tag()),
                }
            },
        ));
    }

    // ---- label party (this thread) -----------------------------------------
    let label_src = Box::new(CsvSource::open(
        &path, FIELDS_A + FIELDS_B, VOCAB)?);
    let mut label_feed = LabelFeed::streaming(
        label_src, FIELDS_A..FIELDS_A + FIELDS_B,
        AlignmentMap::new(seed, OVERLAP), seed, BATCH, CHUNK_ROWS,
        SKIP_ROWS)?;
    let mesh = label_session.mesh();
    let mut scratch = GatherScratch::default();
    let mut label_schedule = Vec::new();
    for round in 0..rounds {
        let (idx, _xb, y) = label_feed.batch(round, &mut scratch)?;
        anyhow::ensure!(y.shape == vec![BATCH], "label batch shape");
        label_schedule.push(idx);
        let mut zsum = None;
        for l in mesh.links() {
            match l.transport.recv()?.into_plain()? {
                Message::Activation { round: r, tensor } => {
                    anyhow::ensure!(r == round, "skew on {}", l.peer);
                    zsum = Some(match zsum {
                        None => tensor,
                        Some(z) => Tensor::sum_f32(&[z, tensor])?,
                    });
                }
                other => anyhow::bail!("unexpected {:?}", other.tag()),
            }
        }
        let zsum = zsum.expect("at least one lane");
        let dz = Tensor::f32(
            zsum.shape.clone(),
            zsum.as_f32()?.iter().map(|x| 0.1 * x).collect::<Vec<_>>(),
        );
        for l in mesh.links() {
            l.transport.send(Message::Derivative {
                round, tensor: dz.clone(),
            })?;
        }
    }
    for l in mesh.links() {
        l.transport.send(Message::Shutdown)?;
    }

    let mut total_ssl = 0u64;
    for h in handles {
        let (schedule, ssl) = h.join().expect("feature panicked")?;
        // Lock-step schedule agreement: every party derived the same
        // aligned batch indices from (seed, file) alone.
        anyhow::ensure!(schedule == label_schedule,
                        "schedules diverged across parties");
        anyhow::ensure!(ssl > 0, "a feature party ran no SSL updates");
        total_ssl += ssl;
    }

    // ---- wire accounting ----------------------------------------------------
    println!("\n{:<8} {:>10} {:>8}", "link", "wire B", "msgs");
    for (peer, stats) in mesh.link_stats() {
        println!("0->{:<5} {:>10} {:>8}", peer.0, stats.bytes,
                 stats.messages);
        // Exactly one derivative per aligned batch plus the shutdown:
        // the SSL work above left no trace on any link.
        anyhow::ensure!(
            stats.messages == rounds + 1,
            "link 0->{peer}: {} messages for {rounds} aligned rounds — \
             unaligned work leaked onto the wire", stats.messages
        );
    }
    std::fs::remove_file(&path).ok();
    println!(
        "\noverlap K=3 smoke OK: {rounds} aligned rounds from a \
         {ROWS}-row CSV at overlap {OVERLAP} (aligned fraction \
         {aligned_frac:.3}), {total_ssl} SSL updates with zero wire \
         traffic"
    );
    Ok(())
}
