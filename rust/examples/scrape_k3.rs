//! K = 3 live-observability smoke: the exporter parity gate
//! (DESIGN.md §10).
//!
//! One process, three session parties over loopback TCP — a label-party
//! session server with the observability plane attached, plus two
//! feature dialers sharing the same metrics registry (single process,
//! so every directed link of the star lands in one scrape). While
//! deterministic protocol-level traffic runs (artifact-free, no PJRT),
//! the orchestrator:
//!
//! 1. scrapes `GET /metrics` off the session port **mid-run** and
//!    checks the exposition is live (round advancing, link families
//!    present);
//! 2. attaches a `GET /watch` client and counts streamed tag-14 frames;
//! 3. at end of run — while the re-admission point still serves — takes
//!    a final scrape and a `RunRecord` terminal snapshot, then lets the
//!    session stop so the watch stream ends with its final frame.
//!
//! The acceptance assertion is three-way parity: the final scrape, the
//! watch stream's last frame, and the `RunRecordObserver` rows must all
//! equal the registry's per-link totals exactly. Exits non-zero on any
//! drift.
//!
//!     cargo run --release --example scrape_k3

use std::io::{Read, Write};
use std::net::TcpStream;
use std::sync::mpsc::channel;
use std::time::{Duration, Instant};

use celu_vfl::config::{RunConfig, WanProfile};
use celu_vfl::metrics::exporters::prometheus;
use celu_vfl::metrics::exporters::push::{frame_rows, read_metrics_frame};
use celu_vfl::metrics::facade::Registry;
use celu_vfl::metrics::{MetricsExporter, RunRecordObserver};
use celu_vfl::protocol::Message;
use celu_vfl::session::bootstrap::{MeshBootstrap, SessionDialer,
                                   SessionListener};
use celu_vfl::session::{PartyId, SessionBuilder, LABEL_PARTY};
use celu_vfl::tensor::Tensor;
use celu_vfl::transport::{LinkStats, Transport};

const ROUNDS: u64 = 12;
const BATCH: usize = 8;
const Z_DIM: usize = 4;
const JOIN_TIMEOUT: Duration = Duration::from_secs(20);
/// Per-round pacing so the run spans several watch ticks (250 ms) and
/// the mid-run scrape genuinely lands mid-run.
const ROUND_PACE: Duration = Duration::from_millis(40);

fn smoke_cfg() -> RunConfig {
    let mut cfg = RunConfig::quick();
    cfg.parties = 3;
    cfg.wan = WanProfile::instant();
    cfg.validate().expect("smoke config invalid");
    cfg
}

/// Deterministic stand-in for a bottom model's activations.
fn synth(party: u16, round: u64) -> Tensor {
    let v: Vec<f32> = (0..BATCH * Z_DIM)
        .map(|i| {
            ((i as f32 * 0.23 + party as f32 * 1.1 + round as f32 * 0.41)
                .sin())
                * 0.7
        })
        .collect();
    Tensor::f32(vec![BATCH, Z_DIM], v)
}

/// One HTTP GET against the session port, to EOF.
fn http_get(addr: &str, path: &str) -> anyhow::Result<String> {
    let mut s = TcpStream::connect(addr)?;
    s.write_all(format!("GET {path} HTTP/1.0\r\n\r\n").as_bytes())?;
    s.set_read_timeout(Some(Duration::from_secs(5)))?;
    let mut out = String::new();
    s.read_to_string(&mut out)?;
    Ok(out)
}

/// Split an HTTP response into (status line, body).
fn split_response(resp: &str) -> anyhow::Result<(&str, &str)> {
    let status = resp.lines().next().unwrap_or("");
    let body = resp
        .split_once("\r\n\r\n")
        .map(|(_, b)| b)
        .ok_or_else(|| anyhow::anyhow!("no header/body split in {resp:?}"))?;
    Ok((status, body))
}

/// The current `celu_session_round` value of an exposition body.
fn scrape_round(body: &str) -> Option<u64> {
    body.lines()
        .find_map(|l| l.strip_prefix("celu_session_round "))
        .and_then(|v| v.parse().ok())
}

fn main() -> anyhow::Result<()> {
    celu_vfl::util::logger::init();
    let cfg = smoke_cfg();
    let registry = Registry::new();

    let listener = SessionListener::bind("127.0.0.1:0")?
        .with_timeout(JOIN_TIMEOUT)
        .with_metrics(registry.clone());
    let addr = listener.local_addr()?.to_string();
    println!("session port: {addr}");

    // Label party: assemble the supervised mesh, drive ROUNDS of
    // Activation → Σ → Derivative traffic, then hold the re-admission
    // point open until the orchestrator has taken its final scrape.
    let (done_tx, done_rx) = channel::<()>();
    let (stop_tx, stop_rx) = channel::<()>();
    let label = std::thread::spawn({
        let cfg = cfg.clone();
        let registry = registry.clone();
        move || -> anyhow::Result<()> {
            let (links, readmission, _epoch, _round) =
                listener.establish_supervised(&cfg)?;
            let mut b = SessionBuilder::new(&cfg, LABEL_PARTY)
                .with_registry(registry.clone());
            for l in links {
                b = b.link_full(l);
            }
            let session = b.build()?;
            for round in 1..=ROUNDS {
                registry.set_round(round);
                let mut zas = Vec::new();
                for l in session.mesh().links() {
                    match l.transport.recv()?.into_plain()? {
                        Message::Activation { round: r, tensor } => {
                            anyhow::ensure!(r == round,
                                            "round skew on {}", l.peer);
                            zas.push(tensor);
                        }
                        other => anyhow::bail!("unexpected tag {}",
                                               other.tag()),
                    }
                }
                let zsum = Tensor::sum_f32(&zas)?;
                let dza = Tensor::f32(
                    zsum.shape.clone(),
                    zsum.as_f32()?
                        .iter()
                        .map(|x| 0.1 * x)
                        .collect::<Vec<_>>(),
                );
                for l in session.mesh().links() {
                    l.transport.send(Message::Derivative {
                        round,
                        tensor: dza.clone(),
                    })?;
                }
                std::thread::sleep(ROUND_PACE);
            }
            for l in session.mesh().links() {
                l.transport.send(Message::Shutdown)?;
            }
            done_tx.send(()).ok();
            // Keep serving scrapes until the orchestrator is done,
            // then drop the re-admission point: its stop flag ends
            // every watch stream with one final-totals frame.
            stop_rx.recv().ok();
            drop(readmission);
            Ok(())
        }
    });

    // Feature parties: dial in, share the one registry (single
    // process), run the matching traffic.
    let features: Vec<_> = [1u16, 2]
        .iter()
        .map(|&p| {
            let cfg = cfg.clone();
            let addr = addr.clone();
            let registry = registry.clone();
            std::thread::spawn(move || -> anyhow::Result<()> {
                let session = SessionBuilder::new(&cfg, PartyId(p))
                    .with_registry(registry)
                    .link_full(
                        SessionDialer::new(&addr, PartyId(p))
                            .with_timeout(JOIN_TIMEOUT)
                            .establish(&cfg)?
                            .remove(0),
                    )
                    .build()?;
                let t = session.mesh().links()[0].transport.clone();
                for round in 1..=ROUNDS {
                    t.send(Message::Activation {
                        round,
                        tensor: synth(p, round),
                    })?;
                    match t.recv()?.into_plain()? {
                        Message::Derivative { round: r, .. } => {
                            anyhow::ensure!(r == round,
                                            "round skew on P{p}");
                        }
                        other => anyhow::bail!("unexpected tag {}",
                                               other.tag()),
                    }
                }
                match t.recv()? {
                    Message::Shutdown => Ok(()),
                    other => anyhow::bail!("expected Shutdown, got tag \
                                            {}", other.tag()),
                }
            })
        })
        .collect();

    // ---- 1. mid-run scrape -------------------------------------------------
    // Poll until the exposition reports a live round: proves the scrape
    // is served while Join vetting and training traffic are in flight.
    let deadline = Instant::now() + Duration::from_secs(15);
    let mid_round = loop {
        anyhow::ensure!(Instant::now() < deadline,
                        "no live scrape before the deadline");
        if let Ok(resp) = http_get(&addr, "/metrics") {
            let (status, body) = split_response(&resp)?;
            anyhow::ensure!(status.contains("200"),
                            "scrape not OK: {status}");
            if let Some(r) = scrape_round(body) {
                if r >= 1 {
                    anyhow::ensure!(
                        body.contains("celu_link_wire_bytes_total{"),
                        "live scrape misses link families:\n{body}"
                    );
                    break r;
                }
            }
        }
        std::thread::sleep(Duration::from_millis(20));
    };
    println!("mid-run scrape OK at round {mid_round}");

    // ---- 2. attach a watch stream ------------------------------------------
    let watcher = std::thread::spawn({
        let addr = addr.clone();
        move || -> anyhow::Result<(u64, Message)> {
            let mut s = TcpStream::connect(&addr)?;
            s.write_all(b"GET /watch HTTP/1.0\r\n\r\n")?;
            s.set_read_timeout(Some(Duration::from_secs(10)))?;
            let mut frames = 0u64;
            let mut last = None;
            while let Ok(f) = read_metrics_frame(&mut s) {
                frames += 1;
                last = Some(f);
            }
            let last = last
                .ok_or_else(|| anyhow::anyhow!("watch delivered no \
                                                frames"))?;
            Ok((frames, last))
        }
    });

    // ---- 3. end of run: final scrape + terminal observer -------------------
    done_rx
        .recv()
        .map_err(|_| anyhow::anyhow!("label thread died mid-run"))?;
    for f in features {
        f.join().expect("feature thread panicked")?;
    }
    // Registry totals are final now; the re-admission point still
    // serves (the label thread waits on stop_tx).
    let resp = http_get(&addr, "/metrics")?;
    let (status, final_body) = split_response(&resp)?;
    anyhow::ensure!(status.contains("200"), "final scrape not OK: \
                                             {status}");
    anyhow::ensure!(
        final_body == prometheus::render(&registry),
        "final scrape differs from a direct render of the registry"
    );
    let observer = RunRecordObserver::new();
    observer.export(&registry)?;
    let record_links = observer.links();
    // Release the session: the watch stream must end with a final
    // frame equal to everything above.
    stop_tx.send(()).ok();
    label.join().expect("label thread panicked")?;
    let (frames, last_frame) = watcher.join().expect("watcher panicked")?;

    // ---- the acceptance assertion ------------------------------------------
    let expected: Vec<(PartyId, PartyId, LinkStats)> = registry
        .link_rows()
        .iter()
        .map(|r| (r.src, r.dst, r.stats))
        .collect();
    anyhow::ensure!(expected.len() == 4,
                    "a K=3 star has 4 directed links, registry has {}",
                    expected.len());
    println!("\n{:<8} {:>10} {:>10} {:>6}   (scrape == watch == record?)",
             "link", "wire B", "raw B", "msgs");
    for (src, dst, s) in &expected {
        let gauge = format!(
            "celu_link_wire_bytes_total{{src=\"{}\",dst=\"{}\"}} {}\n",
            src.0, dst.0, s.bytes
        );
        anyhow::ensure!(final_body.contains(&gauge),
                        "final scrape misses {gauge:?}:\n{final_body}");
        let rec = record_links
            .iter()
            .find(|r| r.src == *src && r.dst == *dst)
            .ok_or_else(|| anyhow::anyhow!("RunRecord misses link \
                                            {src}->{dst}"))?;
        anyhow::ensure!(
            (rec.bytes, rec.raw_bytes, rec.messages)
                == (s.bytes, s.raw_bytes, s.messages),
            "RunRecord row {src}->{dst} diverged from the registry"
        );
        println!("{}->{:<5} {:>10} {:>10} {:>6}   OK",
                 src.0, dst.0, s.bytes, s.raw_bytes, s.messages);
    }
    anyhow::ensure!(
        frame_rows(&last_frame) == expected,
        "watch stream's final frame diverged from the registry:\n  \
         watch:    {:?}\n  registry: {expected:?}",
        frame_rows(&last_frame)
    );
    anyhow::ensure!(last_frame.round() == ROUNDS,
                    "final frame is round {}, expected {ROUNDS}",
                    last_frame.round());
    anyhow::ensure!(frames >= 2,
                    "watch saw only {frames} frame(s) — stream not live");
    println!(
        "\nK=3 observability smoke OK: {frames} watch frames, final \
         scrape == final frame == RunRecord over {} links",
        expected.len()
    );
    Ok(())
}
