//! Offline, API-compatible subset of the `log` facade (vendor/README.md).
//!
//! Provides the macros (`error!` … `trace!`), the `Log` trait, `Level` /
//! `LevelFilter`, `Record` / `Metadata`, and the global logger
//! installation functions — the exact surface `util::logger` and the
//! `log::info!` call sites use. Swap this path dependency for the
//! crates.io release by editing `rust/Cargo.toml`.

use std::fmt;
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::OnceLock;

/// Verbosity level of a single log record.
#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord, Hash)]
pub enum Level {
    Error = 1,
    Warn,
    Info,
    Debug,
    Trace,
}

/// Maximum-verbosity filter installed with [`set_max_level`].
#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord, Hash)]
pub enum LevelFilter {
    Off = 0,
    Error,
    Warn,
    Info,
    Debug,
    Trace,
}

/// Metadata of a record: level + target (the emitting module path).
#[derive(Debug, Clone, Copy)]
pub struct Metadata<'a> {
    level: Level,
    target: &'a str,
}

impl<'a> Metadata<'a> {
    pub fn level(&self) -> Level {
        self.level
    }

    pub fn target(&self) -> &'a str {
        self.target
    }
}

/// One log record, borrowed for the duration of the `Log::log` call.
#[derive(Debug, Clone, Copy)]
pub struct Record<'a> {
    metadata: Metadata<'a>,
    args: fmt::Arguments<'a>,
}

impl<'a> Record<'a> {
    pub fn metadata(&self) -> &Metadata<'a> {
        &self.metadata
    }

    pub fn level(&self) -> Level {
        self.metadata.level
    }

    pub fn target(&self) -> &'a str {
        self.metadata.target
    }

    pub fn args(&self) -> &fmt::Arguments<'a> {
        &self.args
    }
}

/// Backend trait: implemented once per process and installed with
/// [`set_logger`].
pub trait Log: Send + Sync {
    fn enabled(&self, metadata: &Metadata) -> bool;
    fn log(&self, record: &Record);
    fn flush(&self);
}

/// Error returned when [`set_logger`] is called twice.
#[derive(Debug)]
pub struct SetLoggerError(());

impl fmt::Display for SetLoggerError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str("attempted to set a logger after one was already set")
    }
}

impl std::error::Error for SetLoggerError {}

static LOGGER: OnceLock<&'static dyn Log> = OnceLock::new();
static MAX_LEVEL: AtomicUsize = AtomicUsize::new(LevelFilter::Off as usize);

/// Install the global logger. Errors if one is already installed.
pub fn set_logger(logger: &'static dyn Log) -> Result<(), SetLoggerError> {
    LOGGER.set(logger).map_err(|_| SetLoggerError(()))
}

/// Set the global maximum verbosity.
pub fn set_max_level(filter: LevelFilter) {
    MAX_LEVEL.store(filter as usize, Ordering::Relaxed);
}

/// Current global maximum verbosity.
pub fn max_level() -> LevelFilter {
    match MAX_LEVEL.load(Ordering::Relaxed) {
        1 => LevelFilter::Error,
        2 => LevelFilter::Warn,
        3 => LevelFilter::Info,
        4 => LevelFilter::Debug,
        5 => LevelFilter::Trace,
        _ => LevelFilter::Off,
    }
}

/// Macro backend: filters by the global level and dispatches to the
/// installed logger. Public only for the macro expansions.
#[doc(hidden)]
pub fn __private_log(level: Level, target: &str, args: fmt::Arguments) {
    if (level as usize) > MAX_LEVEL.load(Ordering::Relaxed) {
        return;
    }
    if let Some(logger) = LOGGER.get() {
        let record = Record { metadata: Metadata { level, target }, args };
        if logger.enabled(&record.metadata) {
            logger.log(&record);
        }
    }
}

#[macro_export]
macro_rules! log {
    ($lvl:expr, $($arg:tt)+) => {
        $crate::__private_log($lvl, ::std::module_path!(),
                              ::std::format_args!($($arg)+))
    };
}

#[macro_export]
macro_rules! error {
    ($($arg:tt)+) => ($crate::log!($crate::Level::Error, $($arg)+));
}

#[macro_export]
macro_rules! warn {
    ($($arg:tt)+) => ($crate::log!($crate::Level::Warn, $($arg)+));
}

#[macro_export]
macro_rules! info {
    ($($arg:tt)+) => ($crate::log!($crate::Level::Info, $($arg)+));
}

#[macro_export]
macro_rules! debug {
    ($($arg:tt)+) => ($crate::log!($crate::Level::Debug, $($arg)+));
}

#[macro_export]
macro_rules! trace {
    ($($arg:tt)+) => ($crate::log!($crate::Level::Trace, $($arg)+));
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::AtomicU64;

    struct CountingLogger {
        hits: AtomicU64,
    }

    impl Log for CountingLogger {
        fn enabled(&self, _: &Metadata) -> bool {
            true
        }

        fn log(&self, record: &Record) {
            assert!(!record.target().is_empty());
            let _ = format!("{}", record.args());
            self.hits.fetch_add(1, Ordering::Relaxed);
        }

        fn flush(&self) {}
    }

    static TEST_LOGGER: CountingLogger =
        CountingLogger { hits: AtomicU64::new(0) };

    #[test]
    fn filtering_and_dispatch() {
        let _ = set_logger(&TEST_LOGGER);
        set_max_level(LevelFilter::Info);
        assert_eq!(max_level(), LevelFilter::Info);
        let before = TEST_LOGGER.hits.load(Ordering::Relaxed);
        info!("counted {}", 1);
        debug!("not counted");
        let after = TEST_LOGGER.hits.load(Ordering::Relaxed);
        assert_eq!(after - before, 1);
        // Second install attempt fails but is harmless.
        assert!(set_logger(&TEST_LOGGER).is_err());
    }
}
