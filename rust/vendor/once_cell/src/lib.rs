//! Offline, API-compatible subset of `once_cell` (vendor/README.md),
//! backed by `std::sync::OnceLock`.
//!
//! Differences from the crates.io crate, none observable to this repo's
//! call sites: `sync::OnceCell::get_or_try_init` may run the initializer
//! concurrently in more than one thread under a race (the first stored
//! value wins, the losers' values are dropped), and `sync::Lazy` requires
//! `F: Fn` rather than `F: FnOnce` (every use here passes a plain fn
//! pointer). Swap this path dependency for the crates.io release by
//! editing `rust/Cargo.toml`.

pub mod sync {
    use std::ops::Deref;
    use std::sync::OnceLock;

    /// Thread-safe cell initialized at most once (observably).
    #[derive(Debug, Default)]
    pub struct OnceCell<T> {
        inner: OnceLock<T>,
    }

    impl<T> OnceCell<T> {
        pub const fn new() -> Self {
            OnceCell { inner: OnceLock::new() }
        }

        pub fn get(&self) -> Option<&T> {
            self.inner.get()
        }

        pub fn get_or_init<F: FnOnce() -> T>(&self, f: F) -> &T {
            self.inner.get_or_init(f)
        }

        /// Fallible initialization. Under contention the initializer may
        /// run in several threads; exactly one result is stored.
        pub fn get_or_try_init<F, E>(&self, f: F) -> Result<&T, E>
        where
            F: FnOnce() -> Result<T, E>,
        {
            if let Some(v) = self.inner.get() {
                return Ok(v);
            }
            let v = f()?;
            Ok(self.inner.get_or_init(|| v))
        }
    }

    /// Value computed on first dereference.
    #[derive(Debug)]
    pub struct Lazy<T, F = fn() -> T> {
        cell: OnceCell<T>,
        init: F,
    }

    impl<T, F> Lazy<T, F> {
        pub const fn new(init: F) -> Self {
            Lazy { cell: OnceCell::new(), init }
        }
    }

    impl<T, F: Fn() -> T> Lazy<T, F> {
        pub fn force(this: &Self) -> &T {
            this.cell.get_or_init(|| (this.init)())
        }
    }

    impl<T, F: Fn() -> T> Deref for Lazy<T, F> {
        type Target = T;

        fn deref(&self) -> &T {
            Lazy::force(self)
        }
    }
}

#[cfg(test)]
mod tests {
    use super::sync::{Lazy, OnceCell};

    #[test]
    fn once_cell_initializes_once() {
        let c: OnceCell<u32> = OnceCell::new();
        assert!(c.get().is_none());
        assert_eq!(*c.get_or_init(|| 7), 7);
        assert_eq!(*c.get_or_init(|| 9), 7);
        assert_eq!(c.get(), Some(&7));
    }

    #[test]
    fn get_or_try_init_propagates_errors() {
        let c: OnceCell<u32> = OnceCell::new();
        let e: Result<&u32, &str> = c.get_or_try_init(|| Err("nope"));
        assert!(e.is_err());
        assert_eq!(c.get_or_try_init(|| Ok::<_, &str>(3)).unwrap(), &3);
        assert_eq!(c.get_or_try_init(|| Err("ignored")).unwrap(), &3);
    }

    #[test]
    fn lazy_computes_on_deref() {
        static L: Lazy<u64> = Lazy::new(|| 40 + 2);
        assert_eq!(*L, 42);
        assert_eq!(*L, 42);
    }
}
