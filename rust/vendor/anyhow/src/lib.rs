//! Offline, API-compatible subset of the `anyhow` crate (vendor/README.md).
//!
//! Implements exactly the surface this repository uses — `Result`,
//! `Error`, `anyhow!`, `bail!`, `ensure!` — with the same semantics:
//! `Error` is a boxed, chain-preserving error that any
//! `std::error::Error + Send + Sync + 'static` converts into via `?`,
//! `{}` prints the outermost message and `{:#}` prints the full cause
//! chain. Swap this path dependency for the crates.io release by editing
//! `rust/Cargo.toml`; no call site changes are needed.

use std::error::Error as StdError;
use std::fmt;

/// `Result<T, anyhow::Error>` with the error type defaulted.
pub type Result<T, E = Error> = std::result::Result<T, E>;

/// A boxed error with an optional source chain.
pub struct Error {
    msg: String,
    source: Option<Box<dyn StdError + Send + Sync + 'static>>,
}

impl Error {
    /// Construct from a plain message (what `anyhow!` expands to).
    pub fn msg<M: fmt::Display>(message: M) -> Self {
        Error { msg: message.to_string(), source: None }
    }

    /// Iterate the cause chain, outermost first (excluding `self.msg`).
    fn chain(&self) -> impl Iterator<Item = &(dyn StdError + 'static)> {
        let mut next = self
            .source
            .as_deref()
            .map(|s| -> &(dyn StdError + 'static) { s });
        std::iter::from_fn(move || {
            let cur = next?;
            next = cur.source();
            Some(cur)
        })
    }
}

// Like the real anyhow, `Error` deliberately does NOT implement
// `std::error::Error`; that is what makes the blanket `From` below
// coexist with the reflexive `From<Error> for Error`.
impl<E: StdError + Send + Sync + 'static> From<E> for Error {
    fn from(e: E) -> Self {
        Error { msg: e.to_string(), source: Some(Box::new(e)) }
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}", self.msg)?;
        if f.alternate() {
            // `{:#}`: append the cause chain, anyhow-style.
            for cause in self.chain() {
                let s = cause.to_string();
                if s != self.msg {
                    write!(f, ": {s}")?;
                }
            }
        }
        Ok(())
    }
}

impl fmt::Debug for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}", self.msg)?;
        let causes: Vec<String> =
            self.chain().map(|c| c.to_string()).collect();
        if !causes.is_empty() {
            write!(f, "\n\nCaused by:")?;
            for c in causes {
                write!(f, "\n    {c}")?;
            }
        }
        Ok(())
    }
}

/// Construct an [`Error`] from a format string.
#[macro_export]
macro_rules! anyhow {
    ($($arg:tt)*) => {
        $crate::Error::msg(::std::format!($($arg)*))
    };
}

/// Return early with a formatted [`Error`].
#[macro_export]
macro_rules! bail {
    ($($arg:tt)*) => {
        return ::std::result::Result::Err($crate::anyhow!($($arg)*))
    };
}

/// Return early with an [`Error`] when a condition does not hold.
#[macro_export]
macro_rules! ensure {
    ($cond:expr, $($arg:tt)*) => {
        if !($cond) {
            $crate::bail!($($arg)*);
        }
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    fn fails() -> Result<()> {
        bail!("top-level {}", 42);
    }

    #[test]
    fn macros_format() {
        let e = fails().unwrap_err();
        assert_eq!(e.to_string(), "top-level 42");
    }

    #[test]
    fn ensure_passes_and_fails() {
        fn check(x: i32) -> Result<i32> {
            ensure!(x > 0, "x must be positive, got {x}");
            Ok(x)
        }
        assert_eq!(check(3).unwrap(), 3);
        assert!(check(-1).unwrap_err().to_string().contains("-1"));
    }

    #[test]
    fn question_mark_converts_std_errors() {
        fn parse(s: &str) -> Result<i32> {
            Ok(s.parse::<i32>()?)
        }
        assert_eq!(parse("7").unwrap(), 7);
        let e = parse("nope").unwrap_err();
        assert!(!e.to_string().is_empty());
    }

    #[test]
    fn alternate_display_shows_chain() {
        let io = std::io::Error::new(std::io::ErrorKind::Other, "disk bad");
        let e = Error { msg: "loading config".into(),
                        source: Some(Box::new(io)) };
        let s = format!("{e:#}");
        assert!(s.contains("loading config") && s.contains("disk bad"), "{s}");
        let d = format!("{e:?}");
        assert!(d.contains("Caused by"), "{d}");
    }
}
