//! Figure 6 bench: end-to-end wall-clock comparison under the simulated
//! WAN — CELU-VFL vs FedBCD vs Vanilla, WDL + DSSM on the criteo shape.
//!
//! WAN calibration: the paper's regime is 4 MB messages at 300 Mbps
//! (B=4096, d=256), making communication >90% of Vanilla's time. The CI
//! preset sends ~4 KiB messages, so the bench scales the simulated link
//! down (default 1.5 Mbps + 20 ms RTT) to land in the same
//! comm-dominated regime; see EXPERIMENTS.md §Fig6 for the arithmetic.
//!
//! `cargo bench --bench bench_fig6` (env CELU_BENCH_BW_MBPS,
//! CELU_BENCH_ROUNDS, CELU_BENCH_TARGET override).

use celu_vfl::config::{RunConfig, WanProfile};
use celu_vfl::experiments::endtoend;

fn env_f64(name: &str, default: f64) -> f64 {
    std::env::var(name).ok().and_then(|v| v.parse().ok()).unwrap_or(default)
}

fn main() -> anyhow::Result<()> {
    celu_vfl::util::logger::init();
    let mut base = RunConfig::quick();
    base.size = "tiny".into();
    base.max_rounds = env_f64("CELU_BENCH_ROUNDS", 400.0) as usize;
    base.trials = 1;
    base.eval_every = 30;
    base.wan = WanProfile {
        bandwidth_mbps: env_f64("CELU_BENCH_BW_MBPS", 1.5),
        rtt_ms: 20.0,
        gateway_ms: 2.0,
    };
    let target = env_f64("CELU_BENCH_TARGET", 0.70);
    let t0 = std::time::Instant::now();

    println!(
        "== Figure 6 (scaled): {} Mbps WAN, target AUC {target} ==\n",
        base.wan.bandwidth_mbps
    );
    for model in ["wdl", "dssm"] {
        let panel = endtoend::fig6_panel(&base, model, "criteo", 5, target)?;
        endtoend::print_panel(&panel);
        println!();
    }
    println!("total bench time: {:.1}s", t0.elapsed().as_secs_f64());
    Ok(())
}
