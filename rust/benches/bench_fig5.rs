//! Figure 5 bench: regenerates the four ablation panels at CI scale —
//! AUC-vs-rounds series for the R/W/ξ sweeps plus the Fig 5(d) cosine
//! quantile profile and the Theorem-1 ρ probe.
//!
//! `cargo bench --bench bench_fig5`

use celu_vfl::config::RunConfig;
use celu_vfl::experiments::{ablation, theory, SweepResult};

fn print_target_rows(title: &str, sweeps: &[SweepResult], target: f64) {
    println!("[{title}] rounds to AUC {target}:");
    for (label, cell) in ablation::summarize(sweeps, target) {
        println!("  {label:<22} {cell}");
    }
    for s in sweeps {
        println!("  {:<22} best AUC {:.4}", s.label, s.best_auc_mean());
    }
    println!();
}

fn main() -> anyhow::Result<()> {
    celu_vfl::util::logger::init();
    let mut base = RunConfig::quick();
    base.size = "tiny".into();
    base.max_rounds = std::env::var("CELU_BENCH_ROUNDS")
        .ok()
        .and_then(|v| v.parse().ok())
        .unwrap_or(300);
    base.trials = 1;
    base.eval_every = 20;
    base.wan = celu_vfl::config::WanProfile {
        bandwidth_mbps: 6.0, rtt_ms: 10.0, gateway_ms: 1.0 };
    base.r_local = 5;
    base.w_workset = 5;
    base.xi_degrees = 60.0;
    let target = 0.70;
    let t0 = std::time::Instant::now();

    println!("== Figure 5 (scaled) ==\n");

    let mut b = base.clone();
    b.w_workset = 5;
    print_target_rows("5a: local updates (W=5, ξ=60°)",
                      &ablation::sweep_r(&b, &[0, 3, 5, 8])?, target);

    let mut b = base.clone();
    b.r_local = 5;
    print_target_rows("5b: local sampling (R=5, ξ=60°)",
                      &ablation::sweep_w(&b, &[1, 3, 5, 8])?, target);

    print_target_rows("5c: instance weighting (W=5, R=5)",
                      &ablation::sweep_xi(&base, &[180.0, 90.0, 60.0,
                                                   30.0])?, target);

    println!("[5d: cosine-similarity quantiles]");
    let (qa, qb) = ablation::cosine_profile(&base)?;
    let names = ["min", "q10", "q25", "q50", "q75", "q90", "mean",
                 "frac≥cosξ"];
    for (tag, row) in [("A cos(Z)", qa), ("B cos(∇Z)", qb)] {
        if let Some(r) = row {
            print!("  {tag:<12}");
            for (n, v) in names.iter().zip(r.iter()) {
                print!(" {n}={v:.3}");
            }
            println!();
        }
    }

    println!("\n[Theorem 1: ρ vs staleness]");
    let profile = theory::rho_probe(&base, 40, 6, 30)?;
    profile.print();

    println!("\ntotal bench time: {:.1}s", t0.elapsed().as_secs_f64());
    Ok(())
}
