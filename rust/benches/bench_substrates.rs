//! Substrate micro-benchmarks: the L3 building blocks on the hot path.
//!
//! These guard the coordinator-side costs: wire codec, workset table ops,
//! batch gathering, AUC, PRNG and the WAN-delay model. Run via
//! `cargo bench --bench bench_substrates`.

use celu_vfl::config::{Sampling, WanProfile};
use celu_vfl::data::batcher::{gather_a, gather_b};
use celu_vfl::data::SynthDataset;
use celu_vfl::metrics::auc_exact;
use celu_vfl::protocol::Message;
use celu_vfl::tensor::Tensor;
use celu_vfl::testing::bench::{run, section};
use celu_vfl::util::json::Json;
use celu_vfl::util::rng::Pcg;
use celu_vfl::workset::WorksetTable;

fn main() {
    println!("== bench_substrates ==");

    section("PRNG");
    let mut rng = Pcg::seeded(1);
    run("pcg next_u32 x1000", || {
        for _ in 0..1000 {
            std::hint::black_box(rng.next_u32());
        }
    });
    run("pcg next_normal x1000", || {
        for _ in 0..1000 {
            std::hint::black_box(rng.next_normal());
        }
    });

    section("wire codec (B=256, d=64 — 64 KiB activation frame)");
    let msg = Message::Activation {
        round: 7,
        tensor: Tensor::f32(vec![256, 64], vec![0.5; 256 * 64]),
    };
    let encoded = msg.encode();
    run("encode activation", || {
        std::hint::black_box(msg.encode());
    });
    run("decode activation", || {
        std::hint::black_box(Message::decode(&encoded).unwrap());
    });

    section("workset table (W=5, R=5)");
    run("insert+evict cycle", || {
        let mut ws = WorksetTable::new(5, 5, Sampling::RoundRobin);
        for round in 0..32u64 {
            ws.insert(round, vec![0; 256], Tensor::zeros_f32(vec![256, 64]),
                      Tensor::zeros_f32(vec![256, 64]));
        }
        std::hint::black_box(ws.len());
    });
    let mut ws = WorksetTable::new(5, 1_000_000, Sampling::RoundRobin);
    for round in 0..5u64 {
        ws.insert(round, vec![0; 256], Tensor::zeros_f32(vec![256, 64]),
                  Tensor::zeros_f32(vec![256, 64]));
    }
    run("round-robin sample (handle clone, no data copy)", || {
        std::hint::black_box(ws.sample());
    });

    section("data pipeline");
    let ds = SynthDataset::generate("criteo", 1000, 20_000, 2_000, 0.05, 3)
        .unwrap();
    let idx: Vec<u32> = (0..256).collect();
    run("gather_a 256x26", || {
        std::hint::black_box(gather_a(&ds.train_a, &idx));
    });
    run("gather_b 256x13+labels", || {
        std::hint::black_box(gather_b(&ds.train_b, &idx));
    });
    run("synth gen 1k instances", || {
        std::hint::black_box(
            SynthDataset::generate("avazu", 100, 1000, 1, 0.05, 9).unwrap());
    });

    section("metrics");
    let mut rng = Pcg::seeded(5);
    let scores: Vec<f32> = (0..100_000).map(|_| rng.next_f32()).collect();
    let labels: Vec<f32> =
        (0..100_000).map(|_| rng.gen_range(2) as f32).collect();
    run("auc_exact n=100k", || {
        std::hint::black_box(auc_exact(&scores, &labels));
    });

    section("config/json");
    let manifest = std::fs::read_to_string(
        "artifacts/wdl_criteo_tiny/manifest.json");
    if let Ok(src) = manifest {
        run("parse real manifest.json", || {
            std::hint::black_box(Json::parse(&src).unwrap());
        });
    }
    let wan = WanProfile::paper();
    run("wan delay model x1000", || {
        for n in 0..1000usize {
            std::hint::black_box(wan.one_way_delay(n * 64));
        }
    });
}
