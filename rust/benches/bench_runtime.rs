//! Runtime benchmarks: per-step PJRT execute latency for every artifact,
//! plus the host↔literal conversion costs — the L3-side compute budget
//! that the WAN-simulation experiments are calibrated against.
//!
//! `cargo bench --bench bench_runtime [-- <size>]` (default: tiny; pass
//! `small` to measure the experiment-scale artifacts).

use std::sync::Arc;
use std::time::Duration;

use celu_vfl::config::RunConfig;
use celu_vfl::coordinator::trainer::{load_data, load_set};
use celu_vfl::data::batcher::{gather_a, gather_b};
use celu_vfl::runtime::convert::{literal_to_tensor, tensor_to_literal};
use celu_vfl::runtime::{PartyARuntime, PartyBRuntime};
use celu_vfl::tensor::Tensor;
use celu_vfl::testing::bench::{bench, section};

fn main() -> anyhow::Result<()> {
    celu_vfl::util::logger::init();
    let size = std::env::args()
        .skip(1)
        .find(|a| !a.starts_with('-'))
        .unwrap_or_else(|| "tiny".to_string());
    let mut cfg = RunConfig::quick();
    cfg.size = size.clone();
    println!("== bench_runtime ({size} preset) ==");

    let set = load_set(&cfg)?;
    let data = load_data(&cfg, &set)?;
    let m = &set.manifest;
    let batch = m.batch;
    let mut a = PartyARuntime::new(set.clone(), 1, 0.05, 0.5, true)?;
    let mut b = PartyBRuntime::new(set.clone(), 1, 0.05, 0.5, true)?;

    let idx: Vec<u32> = (0..batch as u32).collect();
    let xa = gather_a(&data.train_a, &idx);
    let (xb, y) = gather_b(&data.train_b, &idx);
    let za = a.forward(&xa)?;
    let (dza, _) = b.exact_step(&xb, &y, &za)?;

    let win = Duration::from_secs(2);
    section(&format!("artifact execute (B={batch}, z={}, {} params)",
                     m.z_dim, m.total_params()));
    bench("a_fwd", win, || {
        std::hint::black_box(a.forward(&xa).unwrap());
    })
    .report();
    bench("a_upd (exact update)", win, || {
        a.exact_update(&xa, &dza).unwrap();
    })
    .report();
    bench("a_local (weighted local update)", win, || {
        std::hint::black_box(a.local_update(&xa, &za, &dza).unwrap());
    })
    .report();
    bench("b_step (exact step)", win, || {
        std::hint::black_box(b.exact_step(&xb, &y, &za).unwrap());
    })
    .report();
    bench("b_local (weighted local step)", win, || {
        std::hint::black_box(b.local_step(&xb, &y, &za, &dza).unwrap());
    })
    .report();
    bench("b_eval", win, || {
        std::hint::black_box(b.eval(&xb, &za).unwrap());
    })
    .report();
    bench("a_grad_cos (ρ probe)", win, || {
        std::hint::black_box(a.grad_cos(&xa, &dza, &dza).unwrap());
    })
    .report();

    section("host ↔ literal conversion");
    let t = Tensor::f32(vec![batch, m.z_dim],
                        vec![0.5; batch * m.z_dim]);
    let lit = tensor_to_literal(&t)?;
    bench("tensor→literal [B,z]", win, || {
        std::hint::black_box(tensor_to_literal(&t).unwrap());
    })
    .report();
    bench("literal→tensor [B,z]", win, || {
        std::hint::black_box(literal_to_tensor(&lit).unwrap());
    })
    .report();

    // Round-trip cost summary for calibrating the WAN regime.
    let step = bench("full vanilla round (fwd+step+upd)", win, || {
        let za = a.forward(&xa).unwrap();
        let (dza, _) = b.exact_step(&xb, &y, &za).unwrap();
        a.exact_update(&xa, &dza).unwrap();
    });
    step.report();
    let msg_bytes = (batch * m.z_dim * 4) as f64;
    println!(
        "\ncalibration: activation message = {:.1} KiB; at 300 Mbps one \
         message ≈ {:.2} ms vs compute round ≈ {:.2} ms",
        msg_bytes / 1024.0,
        msg_bytes * 8.0 / 300e6 * 1e3,
        step.mean.as_secs_f64() * 1e3
    );
    let _ = Arc::strong_count(&set);
    Ok(())
}
