//! Hot-path microbench for the zero-copy statistics path (DESIGN.md §4).
//!
//! Three substrates, each printed as ns/op with a bytes-copied estimate
//! so future BENCH files can track the speedups:
//!   1. wire codec — bulk memcpy codec vs the seed's element-wise
//!      baseline (reimplemented here verbatim), on the paper-scale
//!      256×64 f32 activation. Acceptance: ≥ 5× faster roundtrip.
//!   2. workset churn — insert/sample cost across growing batch×dim.
//!      Acceptance: sample cost is flat (handle clone, no data copy).
//!   3. gather — fresh-allocation vs scratch-recycled destination.
//!   4. wire compression — encode+decode throughput per statistics
//!      codec and the resulting wire-bytes-per-round vs the identity
//!      baseline (DESIGN.md §5).
//!   5. session framing — v2 (party-addressed) envelope cost vs the v1
//!      frame, and per-round mesh bytes as the party count K grows
//!      (DESIGN.md §6).
//!   6. bootstrap — time-to-mesh vs K for the in-proc bootstrap (all K
//!      sessions wired + topology-validated through the same
//!      `MeshBootstrap` path a TCP launch takes, DESIGN.md §7); must
//!      stay linear in K and far under a round's WAN cost.
//!   7. metrics facade — the per-send stats bump through pre-registered
//!      `LinkHandles` vs the seed's transport-private `Counters` struct
//!      (reimplemented here verbatim), detached and registry-bound
//!      (DESIGN.md §10). Acceptance: all three are the same four
//!      relaxed `fetch_add`s — the handle bump must stay ≈ 1× the
//!      legacy bump, bound or not.
//!
//! `cargo bench --bench bench_hotpath`

use celu_vfl::compress::{codec_for, CodecKind, StatCodec};
use celu_vfl::config::Sampling;
use celu_vfl::experiments::ablation::{compression_bytes_per_round,
                                      mesh_bytes_per_round};
use celu_vfl::data::batcher::{gather_a, gather_a_with, gather_b_with,
                              GatherScratch};
use celu_vfl::data::SynthDataset;
use celu_vfl::metrics::facade::{LinkHandles, Registry};
use celu_vfl::protocol::{decode_frame, encode_frame_into, FrameHeader,
                         Message};
use celu_vfl::session::bootstrap::inproc_mesh;
use celu_vfl::session::{PartyId, SessionBuilder};
use celu_vfl::tensor::{Data, Tensor};
use celu_vfl::testing::bench::{bench, section};
use celu_vfl::workset::WorksetTable;
use std::hint::black_box;
use std::sync::atomic::AtomicU64;
use std::time::Duration;

const WINDOW: Duration = Duration::from_millis(300);

/// The seed codec's element-wise encode, kept as the comparison baseline.
fn encode_elementwise(msg: &Message) -> Vec<u8> {
    let mut out = Vec::new();
    out.push(msg.tag());
    out.extend_from_slice(&msg.round().to_le_bytes());
    if let Some(t) = msg.tensor() {
        out.push(t.dtype().code());
        out.push(t.shape.len() as u8);
        for &d in &t.shape {
            out.extend_from_slice(&(d as u32).to_le_bytes());
        }
        match &t.data {
            Data::F32(v) => {
                for x in v.iter() {
                    out.extend_from_slice(&x.to_le_bytes());
                }
            }
            Data::I32(v) => {
                for x in v.iter() {
                    out.extend_from_slice(&x.to_le_bytes());
                }
            }
        }
    }
    out
}

/// The seed codec's element-wise payload decode (header handling shared).
fn decode_payload_elementwise(bytes: &[u8]) -> Vec<f32> {
    let mut v = Vec::with_capacity(bytes.len() / 4);
    for c in bytes.chunks_exact(4) {
        v.push(f32::from_le_bytes(c.try_into().unwrap()));
    }
    v
}

fn report(name: &str, r: &celu_vfl::testing::bench::BenchResult,
          bytes_per_op: usize) {
    let ns = r.mean.as_nanos() as f64;
    let gibps = if ns > 0.0 {
        bytes_per_op as f64 / (ns * 1e-9) / (1024.0 * 1024.0 * 1024.0)
    } else {
        f64::INFINITY
    };
    println!("{name:<46} {ns:>12.0} ns/op  {bytes_per_op:>9} B copied  \
              {gibps:>7.2} GiB/s");
}

fn main() {
    println!("== bench_hotpath (zero-copy statistics path) ==");

    // ---- 1. wire codec ---------------------------------------------------
    let payload = 256 * 64 * 4; // bytes in the paper-scale activation
    let msg = Message::Activation {
        round: 7,
        tensor: Tensor::f32(vec![256, 64],
                            (0..256 * 64).map(|i| i as f32 * 0.5)
                                          .collect::<Vec<_>>()),
    };
    let encoded = msg.encode();

    section("wire codec — 256×64 f32 activation (64 KiB payload)");
    let r_enc_old = bench("encode element-wise (seed)", WINDOW, || {
        black_box(encode_elementwise(&msg));
    });
    report("encode element-wise (seed)", &r_enc_old, payload);
    let r_enc = bench("encode bulk", WINDOW, || {
        black_box(msg.encode());
    });
    report("encode bulk", &r_enc, payload);
    let mut scratch = Vec::new();
    let r_enc_into = bench("encode_into reused scratch", WINDOW, || {
        msg.encode_into(&mut scratch);
        black_box(scratch.len());
    });
    report("encode_into reused scratch (0 alloc/op)", &r_enc_into, payload);

    // Header: tag(1) + round(8) + dtype(1) + ndim(1) + 2 dims(8) = 19.
    let body = &encoded[19..];
    let r_dec_old = bench("decode payload element-wise (seed)", WINDOW, || {
        black_box(decode_payload_elementwise(body));
    });
    report("decode payload element-wise (seed)", &r_dec_old, payload);
    let r_dec = bench("decode bulk", WINDOW, || {
        black_box(Message::decode(&encoded).unwrap());
    });
    report("decode bulk (full frame)", &r_dec, payload);

    let old_rt = r_enc_old.mean + r_dec_old.mean;
    let new_rt = r_enc.mean + r_dec.mean;
    let speedup = old_rt.as_secs_f64() / new_rt.as_secs_f64().max(1e-12);
    println!("codec roundtrip: seed {:.2} µs → bulk {:.2} µs  ({speedup:.1}×, \
              target ≥ 5×)",
             old_rt.as_secs_f64() * 1e6, new_rt.as_secs_f64() * 1e6);

    // ---- 2. workset churn ------------------------------------------------
    section("workset sample() across batch×dim — must be flat");
    let mut sample_means = Vec::new();
    for (b, d) in [(64usize, 16usize), (256, 64), (1024, 256)] {
        let mut ws = WorksetTable::new(5, usize::MAX, Sampling::RoundRobin);
        for round in 0..5u64 {
            ws.insert(round, vec![0; b],
                      Tensor::zeros_f32(vec![b, d]),
                      Tensor::zeros_f32(vec![b, d]));
        }
        let r = bench(&format!("sample b={b} d={d}"), WINDOW, || {
            black_box(ws.sample());
        });
        report(&format!("sample b={b} d={d} (0 B tensor copy)"), &r, 0);
        sample_means.push(r.mean.as_nanos() as f64);
    }
    let ratio = sample_means[sample_means.len() - 1]
        / sample_means[0].max(1.0);
    println!("sample cost 1024×256 vs 64×16: {ratio:.2}× \
              (deep copy would be ~256×)");

    section("workset insert+evict churn (W=5, 256×64 entries)");
    let za = Tensor::zeros_f32(vec![256, 64]);
    let dza = Tensor::zeros_f32(vec![256, 64]);
    let mut ws = WorksetTable::new(5, 5, Sampling::RoundRobin);
    let mut round = 0u64;
    let r = bench("insert (shared handles)", WINDOW, || {
        ws.insert(round, vec![0; 256], za.clone(), dza.clone());
        round += 1;
        black_box(ws.len());
    });
    report("insert (shared handles, 0 B tensor copy)", &r, 1024);

    // ---- 3. gather -------------------------------------------------------
    section("gather 256-row batch");
    let ds = SynthDataset::generate("criteo", 1000, 20_000, 2_000, 0.05, 3)
        .unwrap();
    let idx: Vec<u32> = (0..256).collect();
    let a_bytes = 256 * ds.train_a.fields * 4;
    let r = bench("gather_a fresh alloc", WINDOW, || {
        black_box(gather_a(&ds.train_a, &idx));
    });
    report("gather_a fresh alloc", &r, a_bytes);
    let mut scratch = GatherScratch::default();
    let r = bench("gather_a recycled scratch", WINDOW, || {
        black_box(gather_a_with(&ds.train_a, &idx, &mut scratch));
    });
    report("gather_a recycled scratch (0 alloc/op)", &r, a_bytes);
    let b_bytes = 256 * (ds.train_b.fields + 1) * 4;
    let mut scratch = GatherScratch::default();
    let r = bench("gather_b recycled scratch", WINDOW, || {
        black_box(gather_b_with(&ds.train_b, &idx, &mut scratch));
    });
    report("gather_b recycled scratch (0 alloc/op)", &r, b_bytes);

    // ---- 4. wire compression ----------------------------------------------
    section("statistics codecs — 256×64 f32 encode/decode throughput");
    let stats_t = Tensor::f32(vec![256, 64],
                              (0..256 * 64)
                                  .map(|i| (i as f32 * 0.13).sin())
                                  .collect::<Vec<_>>());
    let codecs = [CodecKind::Identity, CodecKind::Fp16,
                  CodecKind::QuantInt8, CodecKind::TopK(1024)];
    for kind in codecs {
        // Measured through the StatCodec trait object — the dispatch
        // cost is part of what an extension codec would pay.
        let codec = codec_for(kind);
        let r = bench(&format!("compress {}", kind.label()), WINDOW, || {
            black_box(codec.compress(&stats_t).unwrap());
        });
        report(&format!("compress {}", kind.label()), &r, payload);
        let block = codec.compress(&stats_t).unwrap();
        let r = bench(&format!("decompress {}", kind.label()), WINDOW,
                      || {
            black_box(codec.decompress(&block).unwrap());
        });
        report(&format!("decompress {}", kind.label()), &r, payload);
    }

    section("wire bytes per round (Z_A + ∇Z_A at 256×64) vs identity");
    let rows = compression_bytes_per_round(256, 64, &codecs).unwrap();
    let ident = rows[0].1 as f64;
    for (label, wire, raw) in &rows {
        println!("{label:<12} {wire:>9} B/round  (raw {raw:>9} B, \
                  {:>5.2}× smaller)",
                 ident / *wire as f64);
    }
    let int8 = rows[2].1;
    let topk = rows[3].1;
    println!("acceptance: int8 {} < identity {} and topk {} < identity \
              {} — {}",
             int8, ident as usize, topk, ident as usize,
             if (int8 as f64) < ident && (topk as f64) < ident {
                 "OK"
             } else {
                 "FAILED"
             });

    // ---- 5. session framing ------------------------------------------------
    section("v2 (party-addressed) framing vs v1 — 256×64 activation");
    let hdr = FrameHeader { src: PartyId(1), dst: PartyId(0) };
    let mut scratch = Vec::new();
    let r_v1 = bench("encode_frame_into v1", WINDOW, || {
        encode_frame_into(None, &msg, &mut scratch);
        black_box(scratch.len());
    });
    report("encode_frame_into v1 (headerless)", &r_v1, payload);
    let r_v2 = bench("encode_frame_into v2", WINDOW, || {
        encode_frame_into(Some(hdr), &msg, &mut scratch);
        black_box(scratch.len());
    });
    report("encode_frame_into v2 (6 B envelope)", &r_v2, payload);
    encode_frame_into(Some(hdr), &msg, &mut scratch);
    let v2_body = scratch[4..].to_vec();
    let r_dec_v2 = bench("decode_frame v2", WINDOW, || {
        black_box(decode_frame(&v2_body).unwrap());
    });
    report("decode_frame v2 (header verify + bulk)", &r_dec_v2, payload);
    let overhead = r_v2.mean.as_secs_f64()
        / r_v1.mean.as_secs_f64().max(1e-12);
    println!("v2 envelope encode overhead: {overhead:.3}× \
              (6 B on a {payload} B payload — must be ~1.0×)");

    section("mesh bytes/round vs party count (identity codec, 256×64)");
    for parties in [2usize, 3, 5, 9] {
        let (_, total) = mesh_bytes_per_round(parties, 256, 64).unwrap();
        println!("K={parties:<3} {:>3} links  {total:>10} B/round",
                 2 * (parties - 1));
    }

    // ---- 6. bootstrap latency ----------------------------------------------
    section("bootstrap — time-to-mesh vs K (in-proc MeshBootstrap)");
    let mut mesh_means = Vec::new();
    for parties in [2usize, 3, 5, 9, 17] {
        let mut cfg = celu_vfl::config::RunConfig::quick();
        cfg.parties = parties;
        let r = bench(&format!("inproc mesh K={parties}"), WINDOW, || {
            // Wire and validate every session of the star: the label
            // party's K−1 links plus one session per feature party —
            // the full cost of a K-party launch minus the sockets.
            let (label_bs, feature_bs) = inproc_mesh(&cfg);
            let label =
                SessionBuilder::from_bootstrap(&cfg, label_bs).unwrap();
            black_box(label.mesh().len());
            for bs in feature_bs {
                let s =
                    SessionBuilder::from_bootstrap(&cfg, bs).unwrap();
                black_box(s.id());
            }
        });
        println!("K={parties:<3} time-to-mesh {:>10.0} ns \
                  ({:>7.0} ns/link)",
                 r.mean.as_nanos() as f64,
                 r.mean.as_nanos() as f64 / (parties - 1) as f64);
        mesh_means.push(r.mean.as_nanos() as f64);
    }
    let growth = mesh_means[mesh_means.len() - 1] / mesh_means[0].max(1.0);
    println!("time-to-mesh K=17 vs K=2: {growth:.1}× \
              (links grew 16×; super-linear growth would flag a \
              bootstrap hot spot)");

    // ---- 7. metrics facade -------------------------------------------------
    section("metrics facade — per-send stats bump (handles vs legacy \
             struct)");
    let wire = 65_536usize;
    let raw = 65_536usize;
    let busy = Duration::from_micros(120);

    let legacy = LegacyCounters::default();
    let r_legacy = bench("legacy Counters::record (seed)", WINDOW, || {
        legacy.record(wire, raw, busy);
        black_box(&legacy);
    });
    report("legacy Counters::record (seed, 4 fetch_add)", &r_legacy, 0);

    let detached = LinkHandles::detached();
    let r_detached = bench("LinkHandles::record detached", WINDOW, || {
        detached.record(wire, raw, busy);
        black_box(&detached);
    });
    report("LinkHandles::record (detached)", &r_detached, 0);

    // Binding the handles into a registry must not touch the hot path:
    // the registry holds clones of the same Arc'd cells, so the bump is
    // byte-for-byte the detached one. This is the API-redesign pin —
    // enabling live observability costs the sender nothing.
    let registry = Registry::new();
    let bound = LinkHandles::detached();
    registry.bind_link(PartyId(1), PartyId(0), &bound);
    let r_bound = bench("LinkHandles::record registry-bound", WINDOW, || {
        bound.record(wire, raw, busy);
        black_box(&bound);
    });
    report("LinkHandles::record (registry-bound)", &r_bound, 0);

    let legacy_ns = r_legacy.mean.as_nanos() as f64;
    let det_x = r_detached.mean.as_nanos() as f64 / legacy_ns.max(1.0);
    let bound_x = r_bound.mean.as_nanos() as f64 / legacy_ns.max(1.0);
    println!("handle bump vs legacy: detached {det_x:.2}×, bound \
              {bound_x:.2}×  (must stay ≈ 1× — same four relaxed \
              fetch_adds, binding only clones Arcs)");
}

/// The seed's transport-private counter struct (pre-facade), kept as
/// the §7 comparison baseline — four relaxed `fetch_add`s per send.
#[derive(Default)]
struct LegacyCounters {
    messages: AtomicU64,
    bytes: AtomicU64,
    raw_bytes: AtomicU64,
    busy_nanos: AtomicU64,
}

impl LegacyCounters {
    fn record(&self, bytes: usize, raw_bytes: usize, busy: Duration) {
        use std::sync::atomic::Ordering;
        self.messages.fetch_add(1, Ordering::Relaxed);
        self.bytes.fetch_add(bytes as u64, Ordering::Relaxed);
        self.raw_bytes.fetch_add(raw_bytes as u64, Ordering::Relaxed);
        self.busy_nanos
            .fetch_add(busy.as_nanos() as u64, Ordering::Relaxed);
    }
}
