//! Table 2 bench: communication rounds to a target AUC across the
//! paper's three technique grids (local update R, local sampling W,
//! instance weighting ξ), at CI scale.
//!
//! The paper's absolute round counts (≈12k–31k on the real Criteo) don't
//! transfer to the synthetic testbed; the *shape* must: every technique
//! cuts rounds vs its baseline, and the orderings match the paper.
//!
//! `cargo bench --bench bench_table2` (env CELU_BENCH_TRIALS, _ROUNDS,
//! _TARGET override the defaults).

use celu_vfl::config::RunConfig;
use celu_vfl::experiments::ablation;

fn env_usize(name: &str, default: usize) -> usize {
    std::env::var(name).ok().and_then(|v| v.parse().ok()).unwrap_or(default)
}

fn env_f64(name: &str, default: f64) -> f64 {
    std::env::var(name).ok().and_then(|v| v.parse().ok()).unwrap_or(default)
}

fn main() -> anyhow::Result<()> {
    celu_vfl::util::logger::init();
    let mut base = RunConfig::quick();
    base.size = "tiny".into();
    base.max_rounds = env_usize("CELU_BENCH_ROUNDS", 300);
    base.trials = env_usize("CELU_BENCH_TRIALS", 1);
    base.eval_every = 20;
    // Comm-bound regime (paper §2.1): scaled link so that R local updates
    // fit inside one communication round — see EXPERIMENTS.md §Calibration.
    base.wan = celu_vfl::config::WanProfile {
        bandwidth_mbps: env_f64("CELU_BENCH_BW_MBPS", 6.0),
        rtt_ms: 10.0,
        gateway_ms: 1.0,
    };
    let target = env_f64("CELU_BENCH_TARGET", 0.70);

    println!(
        "== Table 2 (scaled): rounds to AUC {target}, max {} rounds, {} \
         trial(s) ==\n",
        base.max_rounds, base.trials
    );
    let t0 = std::time::Instant::now();
    match ablation::table2(&base, target) {
        Ok(sections) => {
            for (section, rows) in sections {
                println!("[{section}]");
                for (label, cell) in rows {
                    println!("  {label:<22} {cell}");
                }
                println!();
            }
        }
        Err(e) => {
            // Keep the bench harness alive and loud on partial failure.
            println!("table2 grid failed: {e:#}");
            eprintln!("table2 grid failed: {e:#}");
        }
    }
    println!("total bench time: {:.1}s", t0.elapsed().as_secs_f64());
    Ok(())
}
