//! Service-plane benchmark: admission and steady-state throughput of a
//! multi-session `SessionServer` (DESIGN.md §11).
//!
//! One server socket hosts many concurrent K=3 sessions over loopback
//! TCP; every feature party is an in-process dialer thread. Because
//! the meshes assemble concurrently, every dial takes the full
//! epoch-routing path (`Join` → `NeedRejoin` → `Rejoin`), so the
//! admission figure prices the reactor + routing machinery, not the
//! lucky single-tenant shortcut. Steady-state rounds are fixed-size
//! `EvalAck` ping-pongs — small enough that the number measures the
//! plane's per-round overhead (thread handoffs, transport framing),
//! not tensor bandwidth. Run via `cargo bench --bench bench_serve`.
//!
//! Reported:
//!   - sessions/sec admitted: hosted sessions over the window from
//!     serve() start to the last mesh assembling
//!   - rounds/sec steady-state: aggregate lock-step rounds across all
//!     sessions over the window from first admission to completion

use std::sync::{Arc, Mutex};
use std::time::{Duration, Instant};

use celu_vfl::config::{RunConfig, WanProfile};
use celu_vfl::protocol::Message;
use celu_vfl::session::bootstrap::SessionDialer;
use celu_vfl::session::server::{SessionHandle, SessionServer};
use celu_vfl::session::PartyId;

const SESSIONS: usize = 6;
const ROUNDS: u64 = 200;
const BASE_SEED: u64 = 1000;

fn bench_cfg(seed: u64) -> RunConfig {
    let mut cfg = RunConfig::quick();
    cfg.parties = 3;
    cfg.seed = seed;
    cfg.wan = WanProfile::instant();
    cfg.validate().expect("bench config invalid");
    cfg
}

fn main() {
    println!("== bench_serve ==");
    println!(
        "{SESSIONS} concurrent K=3 sessions, {ROUNDS} control-frame \
         rounds each, one server process/port"
    );

    let mut server = SessionServer::bind("127.0.0.1:0")
        .expect("bind")
        .with_join_timeout(Duration::from_secs(60));
    for i in 0..SESSIONS {
        server.host(bench_cfg(BASE_SEED + i as u64)).expect("host");
    }
    let addr = server.local_addr().expect("addr").to_string();

    // Dialer threads: 2 feature parties per session, all concurrent.
    let mut dialers = Vec::new();
    for i in 0..SESSIONS {
        for party in 1u16..=2 {
            let cfg = bench_cfg(BASE_SEED + i as u64);
            let addr = addr.clone();
            dialers.push(std::thread::spawn(move || {
                let (link, _start) =
                    SessionDialer::new(&addr, PartyId(party))
                        .with_timeout(Duration::from_secs(60))
                        .establish_resumable(&cfg)
                        .expect("dial");
                for round in 0..ROUNDS {
                    match link.transport.recv().expect("recv") {
                        Message::EvalAck { round: r } => {
                            assert_eq!(r, round, "round skew")
                        }
                        other => panic!("unexpected {:?}", other.tag()),
                    }
                    link.transport
                        .send(Message::EvalAck { round })
                        .expect("send");
                }
            }));
        }
    }

    let admissions: Arc<Mutex<Vec<Instant>>> =
        Arc::new(Mutex::new(Vec::new()));
    let admitted = admissions.clone();
    let runner = move |h: SessionHandle| -> anyhow::Result<()> {
        admitted.lock().unwrap().push(Instant::now());
        for round in 0..ROUNDS {
            for link in &h.links {
                link.transport.send(Message::EvalAck { round })?;
            }
            for link in &h.links {
                match link.transport.recv()? {
                    Message::EvalAck { round: r } => {
                        anyhow::ensure!(r == round, "round skew")
                    }
                    other => anyhow::bail!("unexpected {:?}", other.tag()),
                }
            }
        }
        Ok(())
    };

    let start = Instant::now();
    let outcomes = server.serve(runner).expect("serve");
    let end = Instant::now();
    for d in dialers {
        d.join().expect("dialer panicked");
    }
    assert_eq!(outcomes.len(), SESSIONS);
    for o in &outcomes {
        assert!(o.result.is_ok(), "session {} failed: {:?}",
                o.label, o.result);
    }

    let admissions = admissions.lock().unwrap();
    let first_admit = *admissions.iter().min().expect("admissions");
    let last_admit = *admissions.iter().max().expect("admissions");
    let admit_window = (last_admit - start).as_secs_f64().max(1e-9);
    let steady_window = (end - first_admit).as_secs_f64().max(1e-9);
    let total_rounds = (SESSIONS as u64 * ROUNDS) as f64;

    println!(
        "sessions/sec admitted:     {:>10.1}   ({SESSIONS} sessions in \
         {:.3}s)",
        SESSIONS as f64 / admit_window, admit_window
    );
    println!(
        "rounds/sec steady-state:   {:>10.0}   ({total_rounds} rounds \
         in {:.3}s, {} lanes each)",
        total_rounds / steady_window, steady_window, 2
    );
    println!(
        "wall total:                {:>10.3}s",
        (end - start).as_secs_f64()
    );
}
