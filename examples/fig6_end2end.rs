//! Figure 6 reproduction: end-to-end wall-clock comparison.
//!
//! CELU-VFL vs FedBCD vs Vanilla on all three dataset shapes (criteo,
//! avazu, d3) × both models (WDL, DSSM), under the simulated WAN. The
//! paper's headline: CELU-VFL is 2.65–6.27× faster than the competitors
//! to the same validation AUC, and Vanilla spends >90% of its time in
//! communication.
//!
//!     cargo run --release --example fig6_end2end                 # all panels
//!     cargo run --release --example fig6_end2end -- --panel wdl,criteo
//!     cargo run --release --example fig6_end2end -- --list-datasets

use celu_vfl::config::{RunConfig, WanProfile};
use celu_vfl::data::dataset_fields;
use celu_vfl::experiments::endtoend;
use celu_vfl::util::cli::Cli;

fn main() -> anyhow::Result<()> {
    celu_vfl::util::logger::init();
    let cli = Cli::new("fig6_end2end", "Figure 6 reproduction")
        .opt("panel", "all", "'<model>,<dataset>' or 'all'")
        .opt("size", "tiny", "artifact preset (tiny for CI, small for \
                              the full study)")
        .opt("rounds", "3000", "max communication rounds")
        .opt("trials", "1", "trials per competitor (paper: 3)")
        .opt("r", "5", "R for the local-update competitors")
        .opt("target-auc", "0.70", "target validation AUC")
        .opt("bandwidth", "300", "simulated WAN bandwidth (Mbps)")
        .opt("eval-every", "25", "evaluation cadence")
        .opt("max-seconds", "0", "per-run wall budget (0 = unlimited)")
        .flag("list-datasets", "print Table 1 and exit");
    let argv: Vec<String> = std::env::args().skip(1).collect();
    let args = cli.parse(&argv)?;

    if args.has_flag("list-datasets") {
        println!("Table 1 — dataset shapes (synthetic substitutes):");
        println!("{:<10} {:>9} {:>9}", "dataset", "fields A", "fields B");
        for name in ["criteo", "avazu", "d3"] {
            let (fa, fb) = dataset_fields(name)?;
            println!("{name:<10} {fa:>9} {fb:>9}");
        }
        return Ok(());
    }

    let mut base = RunConfig::quick();
    base.size = args.get("size").to_string();
    base.max_rounds = args.get_usize("rounds")?;
    base.trials = args.get_usize("trials")?;
    base.eval_every = args.get_usize("eval-every")?;
    base.max_seconds = args.get_f64("max-seconds")?;
    base.wan = WanProfile {
        bandwidth_mbps: args.get_f64("bandwidth")?,
        ..WanProfile::paper()
    };
    let r = args.get_usize("r")?;
    let target = args.get_f64("target-auc")?;

    let panels: Vec<(String, String)> = match args.get("panel") {
        "all" => ["criteo", "avazu", "d3"]
            .iter()
            .flat_map(|d| {
                [("wdl", *d), ("dssm", *d)]
                    .map(|(m, d)| (m.to_string(), d.to_string()))
            })
            .collect(),
        spec => {
            let (m, d) = spec
                .split_once(',')
                .ok_or_else(|| anyhow::anyhow!("--panel wants \
                                                '<model>,<dataset>'"))?;
            vec![(m.to_string(), d.to_string())]
        }
    };

    println!(
        "== Fig 6: end-to-end, {} preset, {} Mbps WAN, R={r}, target AUC \
         {target} ==\n",
        base.size, base.wan.bandwidth_mbps
    );
    for (model, dataset) in panels {
        let panel = endtoend::fig6_panel(&base, &model, &dataset, r,
                                         target)?;
        endtoend::print_panel(&panel);
        println!();
    }
    Ok(())
}
