//! Two-process deployment demo over real TCP.
//!
//! Run Party B (the label holder / listener) in one terminal and Party A
//! (the feature provider) in another — or let this example fork both
//! roles itself (the default):
//!
//!     cargo run --release --example tcp_two_party                 # forks
//!     cargo run --release --example tcp_two_party -- --role b --addr 127.0.0.1:7643
//!     cargo run --release --example tcp_two_party -- --role a --addr 127.0.0.1:7643
//!
//! Each process loads the artifacts, generates its own vertical slice of
//! the pre-aligned synthetic data (same seed ⇒ same alignment, the
//! post-PSI assumption) and speaks only Z_A/∇Z_A frames on the socket.

use celu_vfl::config::{Algorithm, RunConfig};
use celu_vfl::experiments::tcp::run_tcp_party;
use celu_vfl::util::cli::Cli;

fn config(rounds: usize) -> anyhow::Result<RunConfig> {
    let mut cfg = RunConfig::quick();
    cfg.algorithm = Algorithm::CeluVfl;
    cfg.r_local = 3;
    cfg.w_workset = 3;
    cfg.xi_degrees = 60.0;
    cfg.max_rounds = rounds;
    cfg.eval_every = 25;
    cfg.validate()?;
    Ok(cfg)
}

fn main() -> anyhow::Result<()> {
    celu_vfl::util::logger::init();
    let cli = Cli::new("tcp_two_party", "two-process TCP deployment demo")
        .opt("role", "both", "a | b | both (both forks a child for A)")
        .opt("addr", "127.0.0.1:7643", "socket address")
        .opt("rounds", "150", "communication rounds");
    let argv: Vec<String> = std::env::args().skip(1).collect();
    let args = cli.parse(&argv)?;
    let cfg = config(args.get_usize("rounds")?)?;
    let addr = args.get("addr").to_string();

    let timeout = std::time::Duration::from_secs(30);
    match args.get("role") {
        "a" => run_tcp_party(&cfg, "a", &addr, &addr, 1, timeout),
        "b" => run_tcp_party(&cfg, "b", &addr, &addr, 1, timeout),
        "both" => {
            // Fork Party A as a child process of the same example binary.
            let exe = std::env::current_exe()?;
            let mut child = std::process::Command::new(exe)
                .args(["--role", "a", "--addr", &addr, "--rounds",
                       args.get("rounds")])
                .spawn()?;
            let res = run_tcp_party(&cfg, "b", &addr, &addr, 1, timeout);
            let status = child.wait()?;
            anyhow::ensure!(status.success(), "party A process failed");
            res
        }
        other => anyhow::bail!("role must be a|b|both, got '{other}'"),
    }
}
