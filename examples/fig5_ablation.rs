//! Figure 5 + Table 2 reproduction: ablation and sensitivity study.
//!
//! Panels (paper §5.2, WDL on criteo-shaped data):
//!   a — impact of local updates: R ∈ {1(=vanilla), 3, 5, 8}
//!   b — impact of local sampling: consecutive vs round-robin, W ∈ {1,3,5,8}
//!   c — impact of instance weighting: ξ ∈ {none, 90°, 60°, 30°}
//!   d — cosine-similarity quantiles over training
//!   theory — ρ (grad cosine) vs staleness, the Theorem-1 tradeoff
//!   table2 — the full communication-rounds-to-target grid
//!
//!     cargo run --release --example fig5_ablation -- --panel a
//!     cargo run --release --example fig5_ablation -- --table2 --trials 3

use celu_vfl::config::RunConfig;
use celu_vfl::experiments::{ablation, theory, SweepResult};
use celu_vfl::util::cli::Cli;

fn base_config(args: &celu_vfl::util::cli::Args)
               -> anyhow::Result<RunConfig> {
    let mut cfg = RunConfig::quick();
    cfg.size = args.get("size").to_string();
    cfg.max_rounds = args.get_usize("rounds")?;
    cfg.trials = args.get_usize("trials")?;
    cfg.eval_every = args.get_usize("eval-every")?;
    cfg.r_local = 5;
    cfg.w_workset = 5;
    cfg.xi_degrees = 60.0;
    cfg.validate()?;
    Ok(cfg)
}

fn print_series(sweeps: &[SweepResult], target: f64) {
    // Convergence curves (paper plots AUC vs communication rounds).
    print!("{:<8}", "round");
    for s in sweeps {
        print!(" {:>18}", s.label);
    }
    println!();
    let max_pts = sweeps.iter().map(|s| s.records[0].series.len()).max()
        .unwrap_or(0);
    for i in 0..max_pts {
        let round = sweeps
            .iter()
            .find_map(|s| s.records[0].series.get(i))
            .map(|p| p.comm_round)
            .unwrap_or(0);
        print!("{round:<8}");
        for s in sweeps {
            match s.records[0].series.get(i) {
                Some(p) => print!(" {:>18.4}", p.auc),
                None => print!(" {:>18}", "-"),
            }
        }
        println!();
    }
    println!("\nrounds to target AUC {target:.3} (mean ± std over trials):");
    let rows = ablation::summarize(sweeps, target);
    for (label, cell) in rows {
        println!("  {label:<22} {cell}");
    }
}

fn main() -> anyhow::Result<()> {
    celu_vfl::util::logger::init();
    let cli = Cli::new("fig5_ablation", "Figure 5 / Table 2 reproduction")
        .opt("panel", "a", "a | b | c | d | theory")
        .opt("size", "tiny", "artifact preset")
        .opt("rounds", "600", "max communication rounds per run")
        .opt("trials", "1", "trials per variant (paper: 3)")
        .opt("eval-every", "25", "evaluation cadence (rounds)")
        .opt("target-auc", "0.70", "target AUC for round counting")
        .flag("table2", "run the full Table 2 grid instead of one panel");
    let argv: Vec<String> = std::env::args().skip(1).collect();
    let args = cli.parse(&argv)?;
    let base = base_config(&args)?;
    let target = args.get_f64("target-auc")?;

    if args.has_flag("table2") {
        println!("== Table 2: communication rounds to AUC {target} ==\n");
        for (section, rows) in ablation::table2(&base, target)? {
            println!("[{section}]");
            for (label, cell) in rows {
                println!("  {label:<22} {cell}");
            }
            println!();
        }
        return Ok(());
    }

    match args.get("panel") {
        "a" => {
            println!("== Fig 5(a): impact of local updates (W=5, ξ=60°) ==");
            let mut b = base.clone();
            b.w_workset = 5;
            let sweeps = ablation::sweep_r(&b, &[0, 3, 5, 8])?;
            print_series(&sweeps, target);
        }
        "b" => {
            println!("== Fig 5(b): impact of local sampling (R=5, ξ=60°) ==");
            let mut b = base.clone();
            b.r_local = 5;
            let sweeps = ablation::sweep_w(&b, &[1, 3, 5, 8])?;
            print_series(&sweeps, target);
        }
        "c" => {
            println!("== Fig 5(c): impact of instance weighting (W=5, R=5) \
                      ==");
            let sweeps =
                ablation::sweep_xi(&base, &[180.0, 90.0, 60.0, 30.0])?;
            print_series(&sweeps, target);
        }
        "d" => {
            println!("== Fig 5(d): cosine-similarity quantiles (CELU, W=5, \
                      R=5, ξ=60°) ==");
            let (a, b) = ablation::cosine_profile(&base)?;
            let names = ["min", "q10", "q25", "q50", "q75", "q90", "mean",
                         "frac≥cosξ"];
            if let Some(row) = a {
                println!("party A  cos(Z_new, Z_stale) medians over steps:");
                for (n, v) in names.iter().zip(row.iter()) {
                    println!("  {n:<10} {v:.4}");
                }
            }
            if let Some(row) = b {
                println!("party B  cos(∇Z_new, ∇Z_stale) medians over steps:");
                for (n, v) in names.iter().zip(row.iter()) {
                    println!("  {n:<10} {v:.4}");
                }
            }
        }
        "theory" => {
            println!("== Theorem 1 probe: ρ = cos(g̃, g) vs staleness ==");
            let profile = theory::rho_probe(&base, 50, 8, 40)?;
            profile.print();
            println!(
                "monotone decreasing (slack 0.05): {}",
                profile.is_monotone_decreasing(0.05)
            );
        }
        other => anyhow::bail!("unknown panel '{other}'"),
    }
    Ok(())
}
