//! Quickstart: the smallest complete CELU-VFL run.
//!
//! Trains the WDL model on the synthetic criteo-shaped dataset with the
//! tiny artifact preset, comparing one Vanilla run against one CELU-VFL
//! run at the same communication-round budget, and prints both
//! convergence curves. Runtime: well under a minute on one CPU core.
//!
//!     make artifacts          # once
//!     cargo run --release --example quickstart

use celu_vfl::config::{Algorithm, RunConfig};
use celu_vfl::coordinator::run_training;

fn main() -> anyhow::Result<()> {
    celu_vfl::util::logger::init();

    let mut base = RunConfig::quick();
    base.max_rounds = 300;
    base.eval_every = 25;

    let mut vanilla = base.clone();
    vanilla.algorithm = Algorithm::Vanilla;

    let mut celu = base.clone();
    celu.algorithm = Algorithm::CeluVfl;
    celu.r_local = 3;
    celu.w_workset = 3;
    celu.xi_degrees = 60.0;

    println!("== quickstart: WDL / criteo-shaped synthetic / tiny ==\n");
    let v = run_training(&vanilla)?.record;
    let c = run_training(&celu)?.record;

    println!("\n{:<8} {:>14} {:>14}", "round", "vanilla AUC", "celu AUC");
    for (pv, pc) in v.series.iter().zip(c.series.iter()) {
        println!("{:<8} {:>14.4} {:>14.4}", pv.comm_round, pv.auc, pc.auc);
    }
    println!(
        "\nat {} communication rounds: vanilla best {:.4}, CELU best {:.4} \
         ({} extra local updates, zero extra communication)",
        base.max_rounds,
        v.best_auc(),
        c.best_auc(),
        c.local_updates
    );
    let target = v.best_auc();
    match (c.rounds_to_auc(target), v.rounds_to_auc(target)) {
        (Some(rc), Some(rv)) => println!(
            "rounds to AUC {target:.4}: vanilla {rv}, CELU {rc} \
             (↓{:.0}%)",
            100.0 * (rv as f64 - rc as f64) / rv as f64
        ),
        _ => println!("(target {target:.4} not crossed by both runs)"),
    }
    Ok(())
}
