//! End-to-end driver (the repo's validation workload): trains a
//! ~100M-parameter WDL recommender (`big` artifact preset: 39 embedding
//! fields × 65536 hash buckets × 32 dims + MLPs) with the full CELU-VFL
//! stack — two parties, simulated 300 Mbps WAN, workset table,
//! round-robin local sampling, instance weighting — for a few hundred
//! communication rounds on the synthetic criteo-shaped corpus, logging
//! the loss/AUC curve. Results are recorded in EXPERIMENTS.md §End-to-end.
//!
//!     make artifacts
//!     cargo run --release --example end_to_end            # full (~100M)
//!     cargo run --release --example end_to_end -- --size small   # lighter

use celu_vfl::config::{Algorithm, RunConfig, WanProfile};
use celu_vfl::coordinator::run_training;
use celu_vfl::coordinator::trainer::load_set;
use celu_vfl::util::cli::Cli;

fn main() -> anyhow::Result<()> {
    celu_vfl::util::logger::init();
    let cli = Cli::new("end_to_end", "~100M-param full-stack training run")
        .opt("size", "big", "artifact preset (big = ~100M params)")
        .opt("rounds", "300", "communication rounds")
        .opt("r", "3", "local updates per cached batch")
        .opt("w", "3", "workset capacity")
        .opt("train", "60000", "training instances")
        .opt("out", "results/end_to_end.json", "run-record output");
    let argv: Vec<String> = std::env::args().skip(1).collect();
    let args = cli.parse(&argv)?;

    let mut cfg = RunConfig::quick();
    cfg.model = "wdl".into();
    cfg.dataset = "criteo".into();
    cfg.size = args.get("size").to_string();
    cfg.algorithm = Algorithm::CeluVfl;
    cfg.r_local = args.get_usize("r")?;
    cfg.w_workset = args.get_usize("w")?;
    cfg.xi_degrees = 60.0;
    cfg.max_rounds = args.get_usize("rounds")?;
    cfg.eval_every = (cfg.max_rounds / 12).max(1);
    cfg.eval_batches = 8;
    cfg.train_instances = args.get_usize("train")?;
    cfg.test_instances = 8_192;
    cfg.wan = WanProfile::paper(); // 300 Mbps + gateway, as §2.1
    cfg.validate()?;

    let set = load_set(&cfg)?;
    println!(
        "== end-to-end: {} params, batch {}, z_dim {}, {} rounds, \
         WAN {} Mbps ==",
        set.manifest.total_params(),
        set.manifest.batch,
        set.manifest.z_dim,
        cfg.max_rounds,
        cfg.wan.bandwidth_mbps
    );

    let outcome = run_training(&cfg)?;
    let rec = &outcome.record;
    println!("\n{:<8} {:>10} {:>10} {:>10}", "round", "wall_s", "loss",
             "AUC");
    for p in &rec.series {
        println!("{:<8} {:>10.1} {:>10.4} {:>10.4}", p.comm_round, p.wall_s,
                 p.loss, p.auc);
    }
    println!(
        "\nfinal: best AUC {:.4} | {} comm rounds | {} local updates | \
         wall {:.1}s | comm busy {:.1}s | to-label {:.1} MiB, \
         from-label {:.1} MiB",
        rec.best_auc(),
        rec.comm_rounds,
        rec.local_updates,
        rec.wall.as_secs_f64(),
        rec.comm_busy.as_secs_f64(),
        rec.bytes_to_label() as f64 / (1 << 20) as f64,
        rec.bytes_from_label() as f64 / (1 << 20) as f64,
    );
    if let Some(parent) = std::path::Path::new(args.get("out")).parent() {
        std::fs::create_dir_all(parent)?;
    }
    std::fs::write(args.get("out"), rec.to_json().to_string())?;
    println!("run record written to {}", args.get("out"));
    Ok(())
}
