# Marks tests/ as a package so cross-module imports
# (tests.test_steps → tests.test_models) resolve under
# `python -m pytest python/tests` from the repo root — the exact
# invocation CI uses.
