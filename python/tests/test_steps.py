"""L2 step-function semantics — the heart of the CELU-VFL algorithm.

Checks Algorithm 2 line-by-line: local updates with fresh==stale statistics
and ξ=180° must reproduce the exact update bit-for-bit (weights all 1);
thresholding must drop instances; the two-phase propagation (a_fwd +
b_step + a_upd) must equal a centralized joint gradient step.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile import presets
from compile.models import (bce_rows, bottom_fwd, bottom_param_shapes,
                            split_b_params, top_fwd, top_param_shapes)
from compile.optimizer import adagrad_update
from compile.steps import StepBuilder, WSTATS_LEN
from .test_models import init_params, rand_x

DS = presets.DATASETS["criteo"]
SPEC = presets.SIZES["tiny"]
LR = jnp.float32(0.05)
B = SPEC.batch


def make_state(model, seed=0):
    sa = bottom_param_shapes(model, DS.fields_a, SPEC)
    sb = (bottom_param_shapes(model, DS.fields_b, SPEC)
          + top_param_shapes(model, SPEC))
    pa = init_params(sa, seed=seed)
    pb = init_params(sb, seed=seed + 1)
    aa = [jnp.full_like(p, 0.1) for p in pa]
    ab = [jnp.full_like(p, 0.1) for p in pb]
    return pa, aa, pb, ab


def make_batch(seed=0):
    rng = np.random.default_rng(seed)
    xa = rand_x(DS.fields_a, seed=seed)
    xb = rand_x(DS.fields_b, seed=seed + 1)
    y = jnp.asarray(rng.integers(0, 2, (B,)).astype(np.float32))
    return xa, xb, y


@pytest.mark.parametrize("model", ["wdl", "dssm"])
class TestExactPath:
    def test_two_phase_equals_centralized(self, model):
        """a_fwd → b_step → a_upd == one joint SGD/AdaGrad step."""
        sb = StepBuilder(model, DS, SPEC)
        pa, aa, pb, ab = make_state(model)
        xa, xb, y = make_batch()

        # VFL two-phase protocol.
        (za,) = sb.a_fwd(*pa, xa)
        out = sb.b_step(*pb, *ab, xb, y, za, LR)
        n = len(pb)
        pb2 = list(out[:n])
        dza = out[2 * n]
        out = sb.a_upd(*pa, *aa, xa, dza, LR)
        pa2 = list(out[:len(pa)])

        # Centralized oracle: joint loss over (θ_A, θ_B).
        ones = jnp.ones((B,), jnp.float32)

        def joint_loss(ps_a, ps_b):
            za_ = bottom_fwd(model, ps_a, xa, ones, DS.fields_a, SPEC)
            bot, top = split_b_params(model, ps_b, DS.fields_b, SPEC)
            zb_ = bottom_fwd(model, bot, xb, ones, DS.fields_b, SPEC)
            return jnp.mean(bce_rows(y, top_fwd(model, top, za_, zb_)))

        ga, gb = jax.grad(joint_loss, argnums=(0, 1))(pa, pb)
        pa_ref, _ = adagrad_update(pa, aa, ga, LR)
        pb_ref, _ = adagrad_update(pb, ab, gb, LR)
        for got, want in zip(pa2, pa_ref):
            np.testing.assert_allclose(got, want, rtol=2e-4, atol=1e-6)
        for got, want in zip(pb2, pb_ref):
            np.testing.assert_allclose(got, want, rtol=2e-4, atol=1e-6)

    def test_b_step_dza_is_loss_gradient(self, model):
        sb = StepBuilder(model, DS, SPEC)
        _, _, pb, ab = make_state(model)
        xa, xb, y = make_batch(seed=11)
        za = jnp.asarray(np.random.default_rng(3).normal(
            0, 0.05, (B, SPEC.z_dim)), jnp.float32)
        out = sb.b_step(*pb, *ab, xb, y, za, LR)
        dza = out[2 * len(pb)]
        ones = jnp.ones((B,), jnp.float32)

        def f(za_in):
            bot, top = split_b_params(model, pb, DS.fields_b, SPEC)
            zb = bottom_fwd(model, bot, xb, ones, DS.fields_b, SPEC)
            return jnp.mean(bce_rows(y, top_fwd(model, top, za_in, zb)))

        np.testing.assert_allclose(dza, jax.grad(f)(za), rtol=2e-4,
                                    atol=1e-7)


@pytest.mark.parametrize("model", ["wdl", "dssm"])
class TestLocalPath:
    def test_a_local_fresh_stale_equals_exact(self, model):
        """Stale==ad-hoc statistics + ξ=180° ⇒ weights 1 ⇒ exact a_upd."""
        sb = StepBuilder(model, DS, SPEC)
        pa, aa, _, _ = make_state(model, seed=20)
        xa, _, _ = make_batch(seed=21)
        (za,) = sb.a_fwd(*pa, xa)
        dza = jnp.asarray(np.random.default_rng(5).normal(
            0, 0.01, (B, SPEC.z_dim)), jnp.float32)

        exact = sb.a_upd(*pa, *aa, xa, dza, LR)
        local = sb.a_local(*pa, *aa, xa, za, dza, LR, jnp.float32(-1.0), jnp.float32(1.0))
        n = len(pa)
        wstats = local[-1]
        assert wstats.shape == (WSTATS_LEN,)
        # cos(Z_new, Z_stale) == 1 for every instance ⇒ identical update.
        np.testing.assert_allclose(np.asarray(wstats)[:6], 1.0, rtol=1e-5)
        for got, want in zip(local[:2 * n], exact[:2 * n]):
            np.testing.assert_allclose(got, want, rtol=1e-5, atol=1e-7)

    def test_a_local_threshold_above_one_freezes_params(self, model):
        """cos ξ > 1 zeroes every weight ⇒ zero grads ⇒ params unchanged."""
        sb = StepBuilder(model, DS, SPEC)
        pa, aa, _, _ = make_state(model, seed=30)
        xa, _, _ = make_batch(seed=31)
        (za,) = sb.a_fwd(*pa, xa)
        dza = jnp.asarray(np.random.default_rng(6).normal(
            0, 0.01, (B, SPEC.z_dim)), jnp.float32)
        out = sb.a_local(*pa, *aa, xa, za, dza, LR, jnp.float32(1.5), jnp.float32(1.0))
        for got, want in zip(out[:len(pa)], pa):
            np.testing.assert_allclose(got, want, atol=0)
        assert float(out[-1][-1]) == 0.0  # frac kept

    def test_a_local_unweighted_gate_pins_weights_to_one(self, model):
        """use_weights=0 ⇒ FedBCD semantics: backprop the stale ∇Z_A
        verbatim, regardless of how stale Z_A is ⇒ equals a_upd."""
        sb = StepBuilder(model, DS, SPEC)
        pa, aa, _, _ = make_state(model, seed=25)
        xa, _, _ = make_batch(seed=26)
        rng = np.random.default_rng(27)
        za_stale = jnp.asarray(rng.normal(0, 1.0, (B, SPEC.z_dim)),
                               jnp.float32)  # wildly stale
        dza = jnp.asarray(rng.normal(0, 0.01, (B, SPEC.z_dim)), jnp.float32)
        exact = sb.a_upd(*pa, *aa, xa, dza, LR)
        local = sb.a_local(*pa, *aa, xa, za_stale, dza, LR,
                           jnp.float32(0.5), jnp.float32(0.0))
        for got, want in zip(local[:2 * len(pa)], exact):
            np.testing.assert_allclose(got, want, rtol=1e-5, atol=1e-7)

    def test_b_local_fresh_stale_equals_exact(self, model):
        sb = StepBuilder(model, DS, SPEC)
        pa, _, pb, ab = make_state(model, seed=40)
        xa, xb, y = make_batch(seed=41)
        (za,) = sb.a_fwd(*pa, xa)
        # Derive the true fresh ∇Z_A, then feed it as the "stale" value:
        exact = sb.b_step(*pb, *ab, xb, y, za, LR)
        n = len(pb)
        dza_fresh = exact[2 * n]
        local = sb.b_local(*pb, *ab, xb, y, za, dza_fresh, LR,
                           jnp.float32(-1.0), jnp.float32(1.0))
        for got, want in zip(local[:2 * n], exact[:2 * n]):
            np.testing.assert_allclose(got, want, rtol=2e-4, atol=1e-6)

    def test_b_local_loss_is_weighted(self, model):
        """With cos ξ > 1 every weight is 0 ⇒ reported loss is 0."""
        sb = StepBuilder(model, DS, SPEC)
        pa, _, pb, ab = make_state(model, seed=50)
        xa, xb, y = make_batch(seed=51)
        (za,) = sb.a_fwd(*pa, xa)
        dza = jnp.asarray(np.random.default_rng(7).normal(
            0, 0.01, (B, SPEC.z_dim)), jnp.float32)
        out = sb.b_local(*pb, *ab, xb, y, za, dza, LR, jnp.float32(1.5), jnp.float32(1.0))
        n = len(pb)
        assert float(out[2 * n][0]) == 0.0
        for got, want in zip(out[:n], pb):
            np.testing.assert_allclose(got, want, atol=0)


class TestGradCosProbe:
    def test_same_cotangent_gives_cos_one(self):
        sb = StepBuilder("wdl", DS, SPEC)
        pa, _, _, _ = make_state("wdl", seed=60)
        xa, _, _ = make_batch(seed=61)
        dza = jnp.asarray(np.random.default_rng(8).normal(
            0, 0.01, (B, SPEC.z_dim)), jnp.float32)
        (probe,) = sb.a_grad_cos(*pa, xa, dza, dza)
        assert probe.shape == (3,)
        assert float(probe[0]) == pytest.approx(1.0, rel=1e-5)
        assert float(probe[1]) == pytest.approx(float(probe[2]), rel=1e-6)

    def test_opposite_cotangent_gives_cos_minus_one(self):
        sb = StepBuilder("wdl", DS, SPEC)
        pa, _, _, _ = make_state("wdl", seed=70)
        xa, _, _ = make_batch(seed=71)
        dza = jnp.asarray(np.random.default_rng(9).normal(
            0, 0.01, (B, SPEC.z_dim)), jnp.float32)
        (probe,) = sb.a_grad_cos(*pa, xa, dza, -dza)
        assert float(probe[0]) == pytest.approx(-1.0, rel=1e-5)


class TestWstats:
    def test_quantile_layout(self):
        sb = StepBuilder("wdl", DS, SPEC)
        pa, aa, _, _ = make_state("wdl", seed=80)
        xa, _, _ = make_batch(seed=81)
        (za,) = sb.a_fwd(*pa, xa)
        dza = jnp.asarray(np.random.default_rng(10).normal(
            0, 0.01, (B, SPEC.z_dim)), jnp.float32)
        out = sb.a_local(*pa, *aa, xa, za, dza, LR, jnp.float32(-1.0), jnp.float32(1.0))
        ws = np.asarray(out[-1])
        # quantiles are monotone; mean within [min, max]; frac in [0,1]
        assert np.all(np.diff(ws[:6]) >= -1e-6)
        assert ws[0] - 1e-6 <= ws[6] <= 1.0 + 1e-6
        assert 0.0 <= ws[7] <= 1.0
