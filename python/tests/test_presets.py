"""Preset consistency + paper-protocol checks."""

import pytest

from compile import presets


class TestDatasets:
    def test_table1_field_splits(self):
        """The synthetic datasets keep the paper's Table 1 splits."""
        assert (presets.DATASETS["criteo"].fields_a,
                presets.DATASETS["criteo"].fields_b) == (26, 13)
        assert (presets.DATASETS["avazu"].fields_a,
                presets.DATASETS["avazu"].fields_b) == (14, 8)
        assert (presets.DATASETS["d3"].fields_a,
                presets.DATASETS["d3"].fields_b) == (25, 18)


class TestSizes:
    def test_paper_preset_matches_protocol(self):
        """§5.1: batch 4096, d(Z_A) = 256."""
        p = presets.SIZES["paper"]
        assert p.batch == 4096
        assert p.z_dim == 256

    def test_batches_are_block_friendly(self):
        """Pallas row blocks divide every preset batch (kernel _pick_block
        never falls back to 1)."""
        from compile.kernels.cosine_weights import _pick_block
        for s in presets.SIZES.values():
            assert s.batch % _pick_block(s.batch) == 0
            assert _pick_block(s.batch) >= 32

    def test_big_preset_is_about_100m_params(self):
        """The end-to-end driver advertises a ~100M-parameter model."""
        from compile.models import bottom_param_shapes, top_param_shapes
        ds = presets.DATASETS["criteo"]
        spec = presets.SIZES["big"]
        total = 0
        for fields in (ds.fields_a, ds.fields_b):
            for _, shape in bottom_param_shapes("wdl", fields, spec):
                n = 1
                for d in shape:
                    n *= d
                total += n
        for _, shape in top_param_shapes("wdl", spec):
            n = 1
            for d in shape:
                n *= d
            total += n
        assert 60e6 < total < 150e6, f"big preset has {total} params"


class TestSpecDict:
    def test_spec_dict_roundtrip(self):
        d = presets.spec_dict("wdl", "criteo", "tiny")
        assert d["model"] == "wdl"
        assert d["dataset"]["fields_a"] == 26
        assert d["size"]["batch"] == 64

    def test_unknown_keys_raise(self):
        with pytest.raises(KeyError):
            presets.spec_dict("wdl", "imagenet", "tiny")
        with pytest.raises(KeyError):
            presets.spec_dict("wdl", "criteo", "huge")
