"""Preset consistency + paper-protocol checks."""

import pytest

from compile import presets


class TestDatasets:
    def test_table1_field_splits(self):
        """The synthetic datasets keep the paper's Table 1 splits."""
        assert (presets.DATASETS["criteo"].fields_a,
                presets.DATASETS["criteo"].fields_b) == (26, 13)
        assert (presets.DATASETS["avazu"].fields_a,
                presets.DATASETS["avazu"].fields_b) == (14, 8)
        assert (presets.DATASETS["d3"].fields_a,
                presets.DATASETS["d3"].fields_b) == (25, 18)


class TestSizes:
    def test_paper_preset_matches_protocol(self):
        """§5.1: batch 4096, d(Z_A) = 256."""
        p = presets.SIZES["paper"]
        assert p.batch == 4096
        assert p.z_dim == 256

    def test_batches_are_block_friendly(self):
        """Pallas row blocks divide every preset batch (kernel _pick_block
        never falls back to 1)."""
        from compile.kernels.cosine_weights import _pick_block
        for s in presets.SIZES.values():
            assert s.batch % _pick_block(s.batch) == 0
            assert _pick_block(s.batch) >= 32

    def test_big_preset_is_about_100m_params(self):
        """The end-to-end driver advertises a ~100M-parameter model."""
        from compile.models import bottom_param_shapes, top_param_shapes
        ds = presets.DATASETS["criteo"]
        spec = presets.SIZES["big"]
        total = 0
        for fields in (ds.fields_a, ds.fields_b):
            for _, shape in bottom_param_shapes("wdl", fields, spec):
                n = 1
                for d in shape:
                    n *= d
                total += n
        for _, shape in top_param_shapes("wdl", spec):
            n = 1
            for d in shape:
                n *= d
            total += n
        assert 60e6 < total < 150e6, f"big preset has {total} params"


class TestVerticalSlice:
    """The --parties K artifact preset: fields_a becomes the per-party
    vertical slice width the rust trainer expects (see
    trainer::feature_slices — all slices must match one artifact set,
    so only even splits are valid)."""

    def test_even_splits_give_the_slice_width(self):
        ds = presets.vertical_slice(presets.DATASETS["criteo"], 3)
        assert (ds.fields_a, ds.fields_b) == (13, 13)
        # avazu's 14 A-side fields across 2 and 7 feature parties.
        assert presets.vertical_slice(
            presets.DATASETS["avazu"], 3).fields_a == 7
        assert presets.vertical_slice(
            presets.DATASETS["avazu"], 8).fields_a == 2
        # d3: 25 fields across 5 feature parties.
        assert presets.vertical_slice(
            presets.DATASETS["d3"], 6).fields_a == 5

    def test_label_fields_are_untouched(self):
        for name, ds in presets.DATASETS.items():
            for parties in range(3, ds.fields_a + 2):
                if ds.fields_a % (parties - 1):
                    continue
                sliced = presets.vertical_slice(ds, parties)
                assert sliced.fields_b == ds.fields_b, name
                assert sliced.name == ds.name
                # The slices tile the original feature space exactly.
                assert sliced.fields_a * (parties - 1) == ds.fields_a

    def test_uneven_splits_fail_listing_valid_counts(self):
        with pytest.raises(ValueError) as e:
            presets.vertical_slice(presets.DATASETS["criteo"], 4)
        msg = str(e.value)
        assert "26" in msg and "3 feature parties" in msg
        # The error names the --parties values that would work.
        assert "[3, 14, 27]" in msg

    def test_bounds(self):
        with pytest.raises(ValueError):
            presets.vertical_slice(presets.DATASETS["criteo"], 2)
        with pytest.raises(ValueError):
            presets.vertical_slice(presets.DATASETS["avazu"], 16)


class TestSpecDict:
    def test_spec_dict_roundtrip(self):
        d = presets.spec_dict("wdl", "criteo", "tiny")
        assert d["model"] == "wdl"
        assert d["dataset"]["fields_a"] == 26
        assert d["size"]["batch"] == 64

    def test_unknown_keys_raise(self):
        with pytest.raises(KeyError):
            presets.spec_dict("wdl", "imagenet", "tiny")
        with pytest.raises(KeyError):
            presets.spec_dict("wdl", "criteo", "huge")
