"""AOT exporter tests: manifest schema, HLO-text validity, ABI stability.

These are the build-time guarantees the Rust runtime relies on; a failure
here means the wire ABI drifted and rust/src/runtime/artifacts.rs would
misinterpret the artifacts.
"""

import json
import os

import pytest

from compile import presets
from compile.aot import export_one, to_hlo_text
from compile.models import bottom_param_shapes, top_param_shapes
from compile.steps import WSTATS_LEN


@pytest.fixture(scope="module")
def tiny_export(tmp_path_factory):
    out = str(tmp_path_factory.mktemp("artifacts"))
    manifest = export_one("wdl", "criteo", "tiny", out, verbose=False)
    return out, manifest


EXPECTED_FILES = ("a_fwd", "a_upd", "a_local", "a_grad_cos", "b_step",
                  "b_local", "b_eval")


class TestManifest:
    def test_schema(self, tiny_export):
        _, m = tiny_export
        assert m["abi_version"] == 1
        for key in ("batch", "z_dim", "fields_a", "fields_b", "vocab",
                    "params_a", "params_b", "files"):
            assert key in m
        assert m["wstats_len"] == WSTATS_LEN
        assert set(m["files"]) == set(EXPECTED_FILES)

    def test_param_abi_matches_models(self, tiny_export):
        _, m = tiny_export
        ds, spec = presets.DATASETS["criteo"], presets.SIZES["tiny"]
        want_a = bottom_param_shapes("wdl", ds.fields_a, spec)
        want_b = (bottom_param_shapes("wdl", ds.fields_b, spec)
                  + top_param_shapes("wdl", spec))
        assert [(e["name"], tuple(e["shape"])) for e in m["params_a"]] == \
            [(n, tuple(s)) for n, s in want_a]
        assert [(e["name"], tuple(e["shape"])) for e in m["params_b"]] == \
            [(n, tuple(s)) for n, s in want_b]

    def test_init_kinds(self, tiny_export):
        _, m = tiny_export
        kinds = {e["name"]: e["init"] for e in m["params_a"]}
        assert kinds["emb"] == "normal_0.01"
        assert kinds["w1"] == "glorot"
        assert kinds["b1"] == "zeros"
        assert kinds["wide"] == "zeros"

    def test_manifest_roundtrips_via_json(self, tiny_export):
        out, m = tiny_export
        with open(os.path.join(out, "wdl_criteo_tiny", "manifest.json")) as f:
            loaded = json.load(f)
        assert loaded == m


class TestHloText:
    def test_files_exist_and_parse_shape(self, tiny_export):
        out, m = tiny_export
        d = os.path.join(out, "wdl_criteo_tiny")
        for name in EXPECTED_FILES:
            path = os.path.join(d, m["files"][name])
            assert os.path.exists(path)
            text = open(path).read()
            # HLO text, with an entry computation and a tuple root
            # (return_tuple=True is part of the ABI: rust decomposes it).
            assert "ENTRY" in text
            assert "HloModule" in text

    def test_text_has_no_64bit_id_issue_markers(self, tiny_export):
        """Interchange must be text: no serialized-proto artifacts."""
        out, _ = tiny_export
        d = os.path.join(out, "wdl_criteo_tiny")
        for f in os.listdir(d):
            assert f.endswith((".hlo.txt", ".json"))


class TestDefaultExports:
    def test_matrix_is_well_formed(self):
        for model, dataset, size in presets.DEFAULT_EXPORTS:
            assert model in presets.MODELS
            assert dataset in presets.DATASETS
            assert size in presets.SIZES

    def test_fig6_requirements_covered(self):
        """Figure 6 needs both models on all three datasets at 'small'."""
        small = {(m, d) for m, d, s in presets.DEFAULT_EXPORTS
                 if s == "small"}
        for m in ("wdl", "dssm"):
            for d in ("criteo", "avazu", "d3"):
                assert (m, d) in small
