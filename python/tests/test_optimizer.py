"""AdaGrad unit tests vs a hand-rolled numpy oracle."""

import jax.numpy as jnp
import numpy as np

from compile.optimizer import ADAGRAD_EPS, adagrad_update


def numpy_adagrad(p, a, g, lr):
    a2 = a + g * g
    return p - lr * g / (np.sqrt(a2) + ADAGRAD_EPS), a2


class TestAdaGrad:
    def test_matches_numpy(self):
        rng = np.random.default_rng(0)
        shapes = [(4, 3), (7,), (1,)]
        ps = [rng.normal(size=s).astype(np.float32) for s in shapes]
        gs = [rng.normal(size=s).astype(np.float32) for s in shapes]
        accs = [np.full(s, 0.1, np.float32) for s in shapes]
        new_p, new_a = adagrad_update(
            [jnp.asarray(p) for p in ps], [jnp.asarray(a) for a in accs],
            [jnp.asarray(g) for g in gs], 0.3)
        for p, a, g, np_, na_ in zip(ps, accs, gs, new_p, new_a):
            pr, ar = numpy_adagrad(p, a, g, 0.3)
            np.testing.assert_allclose(np_, pr, rtol=1e-5)
            np.testing.assert_allclose(na_, ar, rtol=1e-6)

    def test_zero_grad_is_identity(self):
        p = jnp.asarray([1.0, -2.0])
        a = jnp.asarray([0.1, 0.1])
        new_p, new_a = adagrad_update([p], [a], [jnp.zeros(2)], 1.0)
        np.testing.assert_allclose(new_p[0], p)
        np.testing.assert_allclose(new_a[0], a)

    def test_effective_step_shrinks_over_repeats(self):
        """Accumulator growth ⇒ monotonically smaller steps (AdaGrad law)."""
        p = jnp.asarray([0.0])
        a = jnp.asarray([0.1])
        g = jnp.asarray([1.0])
        deltas = []
        for _ in range(5):
            (p2,), (a,) = adagrad_update([p], [a], [g], 0.1)
            deltas.append(abs(float(p2[0] - p[0])))
            p = p2
        assert all(d1 > d2 for d1, d2 in zip(deltas, deltas[1:]))
