"""L2 model correctness: shapes, weighting semantics, gradient equivalence.

The key invariant: `dense_weighted`/`scale_bwd` with w=1 must be gradient-
identical to the plain forward (the exact path is the weighted graph with
unit weights), and with arbitrary w must equal the analytically-weighted
per-instance gradients.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile import presets
from compile.models import (bottom_fwd, bottom_param_shapes, dense_weighted,
                            embed, scale_bwd, split_b_params, top_fwd,
                            top_param_shapes, bce_rows)

DS = presets.DATASETS["criteo"]
SPEC = presets.SIZES["tiny"]


def init_params(shapes, seed=0):
    rng = np.random.default_rng(seed)
    out = []
    for name, s in shapes:
        if name == "emb":
            out.append(rng.normal(0, 0.01, s))
        elif name.startswith("w") and name not in ("wide", "wide_top"):
            lim = np.sqrt(6.0 / (s[0] + s[-1]))
            out.append(rng.uniform(-lim, lim, s))
        elif name == "scale":
            out.append(np.ones(s))
        else:
            out.append(np.zeros(s))
    return [jnp.asarray(p, jnp.float32) for p in out]


def rand_x(fields, batch=SPEC.batch, seed=1):
    rng = np.random.default_rng(seed)
    return jnp.asarray(
        rng.integers(0, SPEC.vocab, (batch, fields)).astype(np.int32))


class TestEmbed:
    def test_shape_and_gather_semantics(self):
        table = jnp.arange(2 * SPEC.vocab * 3, dtype=jnp.float32).reshape(
            2 * SPEC.vocab, 3)
        x = jnp.asarray([[0, 0], [1, SPEC.vocab - 1]], jnp.int32)
        e = embed(table, x, 2, SPEC.vocab)
        assert e.shape == (2, 6)
        # field f id i → row f*vocab + i
        np.testing.assert_allclose(e[0, :3], table[0])
        np.testing.assert_allclose(e[0, 3:], table[SPEC.vocab])
        np.testing.assert_allclose(e[1, :3], table[1])
        np.testing.assert_allclose(e[1, 3:], table[2 * SPEC.vocab - 1])


class TestDenseWeighted:
    def test_forward_ignores_weights(self):
        rng = np.random.default_rng(0)
        h = jnp.asarray(rng.normal(size=(8, 4)), jnp.float32)
        w_mat = jnp.asarray(rng.normal(size=(4, 3)), jnp.float32)
        b = jnp.asarray(rng.normal(size=(3,)), jnp.float32)
        z1 = dense_weighted(h, w_mat, b, jnp.ones((8,)))
        z2 = dense_weighted(h, w_mat, b, jnp.zeros((8,)))
        np.testing.assert_allclose(z1, z2)
        np.testing.assert_allclose(z1, h @ w_mat + b, rtol=1e-6)

    def test_backward_weights_per_instance(self):
        rng = np.random.default_rng(1)
        h = jnp.asarray(rng.normal(size=(8, 4)), jnp.float32)
        w_mat = jnp.asarray(rng.normal(size=(4, 3)), jnp.float32)
        b = jnp.zeros((3,), jnp.float32)
        ct = jnp.asarray(rng.normal(size=(8, 3)), jnp.float32)
        w = jnp.asarray(rng.uniform(0, 1, (8,)), jnp.float32)

        def f(hh, ww, bb):
            return jnp.sum(dense_weighted(hh, ww, bb, w) * ct)

        dh, dw, db = jax.grad(f, argnums=(0, 1, 2))(h, w_mat, b)
        ctw = ct * w[:, None]
        np.testing.assert_allclose(dh, ctw @ w_mat.T, rtol=1e-4, atol=1e-5)
        np.testing.assert_allclose(dw, h.T @ ctw, rtol=1e-4, atol=1e-5)
        np.testing.assert_allclose(db, ctw.sum(0), rtol=1e-4, atol=1e-5)

    def test_unit_weights_match_plain_autodiff(self):
        rng = np.random.default_rng(2)
        h = jnp.asarray(rng.normal(size=(16, 4)), jnp.float32)
        w_mat = jnp.asarray(rng.normal(size=(4, 3)), jnp.float32)
        b = jnp.asarray(rng.normal(size=(3,)), jnp.float32)
        ct = jnp.asarray(rng.normal(size=(16, 3)), jnp.float32)
        ones = jnp.ones((16,), jnp.float32)
        g1 = jax.grad(lambda ww: jnp.sum(dense_weighted(h, ww, b, ones) * ct))(w_mat)
        g2 = jax.grad(lambda ww: jnp.sum((h @ ww + b) * ct))(w_mat)
        np.testing.assert_allclose(g1, g2, rtol=1e-4, atol=1e-5)


class TestScaleBwd:
    def test_identity_forward_scaled_backward(self):
        v = jnp.asarray(np.random.default_rng(0).normal(size=(8, 2)),
                        jnp.float32)
        w = jnp.asarray(np.linspace(0, 1, 8), jnp.float32)
        np.testing.assert_allclose(scale_bwd(v, w), v)
        g = jax.grad(lambda vv: jnp.sum(scale_bwd(vv, w)))(v)
        np.testing.assert_allclose(g, np.broadcast_to(
            np.asarray(w)[:, None], (8, 2)), rtol=1e-6)


@pytest.mark.parametrize("model", ["wdl", "dssm"])
class TestBottomTop:
    def test_shapes(self, model):
        shapes = bottom_param_shapes(model, DS.fields_a, SPEC)
        params = init_params(shapes)
        x = rand_x(DS.fields_a)
        z = bottom_fwd(model, params, x, jnp.ones((SPEC.batch,)), DS.fields_a,
                       SPEC)
        assert z.shape == (SPEC.batch, SPEC.z_dim)
        assert z.dtype == jnp.float32

        pb = init_params(bottom_param_shapes(model, DS.fields_b, SPEC)
                         + top_param_shapes(model, SPEC), seed=3)
        bot, top = split_b_params(model, pb, DS.fields_b, SPEC)
        zb = bottom_fwd(model, bot, rand_x(DS.fields_b), jnp.ones((SPEC.batch,)),
                        DS.fields_b, SPEC)
        logits = top_fwd(model, top, z, zb)
        assert logits.shape == (SPEC.batch,)

    def test_weights_do_not_change_forward(self, model):
        shapes = bottom_param_shapes(model, DS.fields_a, SPEC)
        params = init_params(shapes, seed=5)
        x = rand_x(DS.fields_a, seed=6)
        rng = np.random.default_rng(7)
        w = jnp.asarray(rng.uniform(0, 1, (SPEC.batch,)), jnp.float32)
        z1 = bottom_fwd(model, params, x, w, DS.fields_a, SPEC)
        z2 = bottom_fwd(model, params, x, jnp.ones((SPEC.batch,)),
                        DS.fields_a, SPEC)
        np.testing.assert_allclose(z1, z2, rtol=1e-6)

    def test_zero_weights_zero_all_param_grads(self, model):
        shapes = bottom_param_shapes(model, DS.fields_a, SPEC)
        params = init_params(shapes, seed=8)
        x = rand_x(DS.fields_a, seed=9)
        ct = jnp.asarray(np.random.default_rng(10).normal(
            size=(SPEC.batch, SPEC.z_dim)), jnp.float32)
        zeros = jnp.zeros((SPEC.batch,), jnp.float32)

        def f(ps):
            return jnp.sum(bottom_fwd(model, ps, x, zeros, DS.fields_a,
                                      SPEC) * ct)

        grads = jax.grad(f)(params)
        for g in grads:
            assert float(jnp.max(jnp.abs(g))) == 0.0


class TestLoss:
    def test_bce_matches_naive(self):
        rng = np.random.default_rng(0)
        logits = jnp.asarray(rng.normal(0, 3, (64,)), jnp.float32)
        y = jnp.asarray(rng.integers(0, 2, (64,)), jnp.float32)
        p = jax.nn.sigmoid(logits)
        naive = -(y * jnp.log(p) + (1 - y) * jnp.log(1 - p))
        np.testing.assert_allclose(bce_rows(y, logits), naive, rtol=1e-4,
                                   atol=1e-5)

    def test_bce_stable_at_extreme_logits(self):
        logits = jnp.asarray([100.0, -100.0], jnp.float32)
        y = jnp.asarray([1.0, 0.0], jnp.float32)
        rows = bce_rows(y, logits)
        assert np.all(np.isfinite(np.asarray(rows)))
        np.testing.assert_allclose(rows, [0.0, 0.0], atol=1e-6)
