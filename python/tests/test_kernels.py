"""L1 correctness: Pallas kernels vs pure-jnp oracles (ref.py).

hypothesis sweeps shapes (incl. non-pow2 row counts exercising every block
size the picker can choose), value scales, and degenerate inputs (zero
rows, identical rows). This is the core kernel-correctness signal.
"""

import jax.numpy as jnp
import numpy as np
import pytest

# Skip (don't error) the whole module where hypothesis isn't installed —
# offline dev boxes; CI installs it and runs the full sweep.
pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st

from compile.kernels import apply_weights, cosine_weights, weighted_grad
from compile.kernels.cosine_weights import _pick_block
from compile.kernels.ref import (apply_weights_ref, cosine_weights_ref,
                                 weighted_grad_ref)

DIMS = st.tuples(st.sampled_from([1, 2, 3, 4, 6, 8, 16, 64, 96, 128, 160]),
                 st.sampled_from([1, 2, 5, 16, 33, 64]))


def _rand(rng, shape, scale):
    return jnp.asarray(rng.normal(0.0, scale, shape).astype(np.float32))


class TestPickBlock:
    def test_divides(self):
        for b in (1, 2, 3, 5, 64, 96, 100, 128, 256, 4096):
            blk = _pick_block(b)
            assert b % blk == 0 and 1 <= blk <= 128

    def test_prefers_large(self):
        assert _pick_block(4096) == 128
        assert _pick_block(64) == 64
        assert _pick_block(96) == 32


class TestCosineWeights:
    @settings(max_examples=40, deadline=None)
    @given(dims=DIMS, scale=st.sampled_from([1e-3, 1.0, 1e3]),
           thr=st.sampled_from([-1.0, 0.0, 0.5, 0.866]),
           seed=st.integers(0, 2**31 - 1))
    def test_matches_ref(self, dims, scale, thr, seed):
        b, d = dims
        rng = np.random.default_rng(seed)
        vn, vs = _rand(rng, (b, d), scale), _rand(rng, (b, d), scale)
        w, cos = cosine_weights(vn, vs, thr)
        wr, cr = cosine_weights_ref(vn, vs, thr)
        np.testing.assert_allclose(cos, cr, rtol=1e-5, atol=1e-6)
        np.testing.assert_allclose(w, wr, rtol=1e-5, atol=1e-6)

    def test_identical_rows_give_weight_one(self):
        v = _rand(np.random.default_rng(0), (64, 16), 1.0)
        w, cos = cosine_weights(v, v, 0.9)
        np.testing.assert_allclose(w, np.ones(64), rtol=1e-5)
        np.testing.assert_allclose(cos, np.ones(64), rtol=1e-5)

    def test_opposite_rows_thresholded_to_zero(self):
        v = _rand(np.random.default_rng(1), (32, 8), 1.0)
        w, cos = cosine_weights(v, -v, 0.0)
        np.testing.assert_allclose(cos, -np.ones(32), rtol=1e-5)
        assert np.all(np.asarray(w) == 0.0)

    def test_zero_row_maps_to_zero_weight(self):
        vn = jnp.zeros((4, 8), jnp.float32)
        vs = jnp.ones((4, 8), jnp.float32)
        w, cos = cosine_weights(vn, vs, 0.0)
        assert np.all(np.isfinite(np.asarray(cos)))
        np.testing.assert_allclose(w, np.zeros(4))

    def test_threshold_boundary_keeps_cos_at_exact_threshold(self):
        # rows with cos exactly ~0: threshold 0.0 keeps them (>=).
        vn = jnp.asarray([[1.0, 0.0], [0.0, 1.0]], jnp.float32)
        vs = jnp.asarray([[0.0, 1.0], [0.0, 1.0]], jnp.float32)
        w, _ = cosine_weights(vn, vs, 0.0)
        assert np.asarray(w)[0] == pytest.approx(0.0, abs=1e-6)
        assert np.asarray(w)[1] == pytest.approx(1.0, rel=1e-5)


class TestApplyWeights:
    @settings(max_examples=30, deadline=None)
    @given(dims=DIMS, seed=st.integers(0, 2**31 - 1))
    def test_matches_ref(self, dims, seed):
        b, d = dims
        rng = np.random.default_rng(seed)
        v = _rand(rng, (b, d), 1.0)
        w = jnp.abs(_rand(rng, (b,), 1.0))
        np.testing.assert_allclose(apply_weights(v, w),
                                   apply_weights_ref(v, w), rtol=1e-6)

    def test_zero_weights_zero_rows(self):
        v = _rand(np.random.default_rng(2), (16, 4), 1.0)
        out = apply_weights(v, jnp.zeros((16,), jnp.float32))
        assert np.all(np.asarray(out) == 0.0)


class TestWeightedGrad:
    @settings(max_examples=30, deadline=None)
    @given(b=st.sampled_from([1, 2, 4, 64, 96, 128, 192]),
           din=st.sampled_from([1, 3, 8, 32]),
           dout=st.sampled_from([1, 2, 16, 24]),
           seed=st.integers(0, 2**31 - 1))
    def test_matches_ref(self, b, din, dout, seed):
        rng = np.random.default_rng(seed)
        a = _rand(rng, (b, din), 1.0)
        g = _rand(rng, (b, dout), 1.0)
        w = jnp.abs(_rand(rng, (b,), 1.0))
        np.testing.assert_allclose(weighted_grad(a, g, w),
                                   weighted_grad_ref(a, g, w),
                                   rtol=1e-4, atol=1e-5)

    def test_unit_weights_reduce_to_plain_matmul(self):
        rng = np.random.default_rng(3)
        a, g = _rand(rng, (64, 8), 1.0), _rand(rng, (64, 4), 1.0)
        out = weighted_grad(a, g, jnp.ones((64,), jnp.float32))
        np.testing.assert_allclose(out, a.T @ g, rtol=1e-4, atol=1e-5)

    def test_accumulation_across_grid_steps(self):
        # b=256 with blk=128 → 2 grid steps exercising the += branch.
        rng = np.random.default_rng(4)
        a, g = _rand(rng, (256, 8), 1.0), _rand(rng, (256, 8), 1.0)
        w = jnp.abs(_rand(rng, (256,), 1.0))
        np.testing.assert_allclose(weighted_grad(a, g, w),
                                   weighted_grad_ref(a, g, w),
                                   rtol=1e-4, atol=1e-5)
