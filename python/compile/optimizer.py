"""AdaGrad optimizer as a pure functional update (the paper's protocol §5.1).

State is one accumulator per parameter (sum of squared gradients). The
learning rate is a runtime scalar input so the Rust coordinator can tune it
without re-exporting artifacts. The initial accumulator value (0.1, the
TensorFlow default the paper's implementation inherits) is set by the Rust
parameter store at init time, not here.
"""

import jax.numpy as jnp

ADAGRAD_EPS = 1e-8
ADAGRAD_INIT_ACC = 0.1  # documented for the rust side; see runtime/params.rs


def adagrad_update(params, accs, grads, lr):
    """One AdaGrad step over flat param/accumulator/grad lists.

    acc' = acc + g²;  θ' = θ − lr · g / (√acc' + ε)
    Returns (new_params, new_accs) as flat lists in the same order.
    """
    new_params, new_accs = [], []
    for p, a, g in zip(params, accs, grads):
        a2 = a + g * g
        new_params.append(p - lr * g / (jnp.sqrt(a2) + ADAGRAD_EPS))
        new_accs.append(a2)
    return new_params, new_accs
