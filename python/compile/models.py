"""L2: the VFL model zoo — bottom/top networks for WDL and DSSM.

The paper evaluates two deep-learning recommendation models (§5.1):

- **WDL** (Wide & Deep): each party's bottom model embeds its hashed
  categorical fields, runs a deep MLP, and appends a "wide" linear-path
  scalar; Party B's top model is an MLP (+ wide linear) over the
  concatenated [Z_A, Z_B].
- **DSSM** (Deep Structured Semantic Model): two-tower — Party A's bottom
  is the user tower, Party B's the item tower; the top model is a scaled
  dot-product of the towers.

Parameters are FLAT POSITIONAL LISTS with a fixed documented order (see
`bottom_param_shapes` / `top_param_shapes`): the Rust coordinator holds
them as opaque device buffers and re-feeds them positionally, so the order
here is the wire ABI. Initialisation is done on the Rust side (glorot for
matrices, zeros for biases, scaled-normal for embeddings) from the shapes
recorded in the manifest.

Instance weighting is threaded through the bottom model: the output dense
layer is `dense_weighted` (custom_vjp) whose backward applies the CELU-VFL
staleness weights through the Pallas kernels (weighted_grad for dW,
apply_weights for the flowing cotangent). The exact (non-local) step passes
w = 1, making the weighted graph the single code path for both exact and
local updates.
"""

import jax
import jax.numpy as jnp

from .kernels import apply_weights, weighted_grad


# --------------------------------------------------------------------------
# Weighted dense output layer (custom VJP → Pallas kernels on backward).
# --------------------------------------------------------------------------

@jax.custom_vjp
def dense_weighted(h, w_mat, b, ins_w):
    """z = h @ w_mat + b; backward scales per-instance grads by ins_w."""
    return h @ w_mat + b


def _dense_weighted_fwd(h, w_mat, b, ins_w):
    return dense_weighted(h, w_mat, b, ins_w), (h, w_mat, ins_w)


def _dense_weighted_bwd(res, g):
    h, w_mat, ins_w = res
    gw = apply_weights(g, ins_w)          # Pallas: w ⊙ g, fused
    dh = gw @ w_mat.T
    dw = weighted_grad(h, g, ins_w)       # Pallas: h^T (w ⊙ g), fused
    db = jnp.sum(gw, axis=0)
    return dh, dw, db, None


dense_weighted.defvjp(_dense_weighted_fwd, _dense_weighted_bwd)


@jax.custom_vjp
def scale_bwd(v, ins_w):
    """Identity forward; backward scales the cotangent rows by ins_w.

    Used to weight side paths (the WDL wide path) that do not go through
    dense_weighted.
    """
    return v


def _scale_bwd_fwd(v, ins_w):
    return v, ins_w


def _scale_bwd_bwd(ins_w, g):
    return apply_weights(g, ins_w), None


scale_bwd.defvjp(_scale_bwd_fwd, _scale_bwd_bwd)


# --------------------------------------------------------------------------
# Bottom models. x: int32 [B, F] hashed ids in [0, vocab).
# --------------------------------------------------------------------------

def embed(table, x, fields, vocab):
    """Per-field embedding lookup: table [F·V, De], x [B, F] → [B, F·De]."""
    offsets = jnp.arange(fields, dtype=jnp.int32) * vocab
    idx = x + offsets[None, :]
    e = jnp.take(table, idx, axis=0)          # [B, F, De]
    return e.reshape(x.shape[0], -1)


def bottom_param_shapes(model, fields, spec):
    """Flat param order of one party's bottom model. The wire ABI."""
    fv = fields * spec.vocab
    fde = fields * spec.emb_dim
    shapes = [
        ("emb", (fv, spec.emb_dim)),
        ("w1", (fde, spec.hidden)),
        ("b1", (spec.hidden,)),
        ("w2", (spec.hidden, spec.z_dim)),
        ("b2", (spec.z_dim,)),
    ]
    if model == "wdl":
        shapes.append(("wide", (fv, 1)))
    return shapes


def bottom_fwd(model, params, x, ins_w, fields, spec):
    """Party bottom model: Z_P = Bottom_P(X_P; θ).  Returns [B, z_dim].

    ins_w [B] are CELU-VFL instance weights applied on the backward pass
    (pass ones for the exact path).
    """
    if model == "wdl":
        emb, w1, b1, w2, b2, wide = params
    else:
        emb, w1, b1, w2, b2 = params
    e = embed(emb, x, fields, spec.vocab)
    h1 = jax.nn.relu(e @ w1 + b1)
    z = dense_weighted(h1, w2, b2, ins_w)
    if model == "wdl":
        # Wide path: per-field scalar weights summed, folded into the first
        # z coordinate (keeps z_dim uniform across models for the wire).
        offsets = jnp.arange(fields, dtype=jnp.int32) * spec.vocab
        zw = jnp.sum(jnp.take(wide[:, 0], x + offsets[None, :], axis=0),
                     axis=1, keepdims=True)
        zw = scale_bwd(zw, ins_w)
        z = z + jnp.pad(zw, ((0, 0), (0, spec.z_dim - 1)))
    return z


# --------------------------------------------------------------------------
# Top models (Party B only).
# --------------------------------------------------------------------------

def top_param_shapes(model, spec):
    """Flat param order of the top model."""
    zd2 = 2 * spec.z_dim
    if model == "wdl":
        return [
            ("wt1", (zd2, spec.top_hidden)),
            ("bt1", (spec.top_hidden,)),
            ("wt2", (spec.top_hidden, 1)),
            ("bt2", (1,)),
            ("wide_top", (zd2, 1)),
        ]
    # DSSM: scaled dot-product scorer.
    return [("scale", (1,)), ("bias", (1,))]


def top_fwd(model, params, za, zb):
    """ŷ logits = Top(Z_A, Z_B; θ_top).  Returns [B]."""
    if model == "wdl":
        wt1, bt1, wt2, bt2, wide_top = params
        zcat = jnp.concatenate([za, zb], axis=1)
        h = jax.nn.relu(zcat @ wt1 + bt1)
        deep = (h @ wt2 + bt2)[:, 0]
        wide = (zcat @ wide_top)[:, 0]
        return deep + wide
    scale, bias = params
    return scale[0] * jnp.sum(za * zb, axis=1) + bias[0]


def bce_rows(y, logits):
    """Per-instance numerically-stable sigmoid binary cross-entropy [B]."""
    return jnp.maximum(logits, 0.0) - logits * y + jnp.log1p(
        jnp.exp(-jnp.abs(logits)))


def split_b_params(model, params_b, fields_b, spec):
    """Party B's flat list = bottom params ++ top params."""
    nb = len(bottom_param_shapes(model, fields_b, spec))
    return params_b[:nb], params_b[nb:]
