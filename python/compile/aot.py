"""AOT exporter: lowers every step function to HLO *text* + a JSON manifest.

Run once via `make artifacts` (no Python on the training path):

    cd python && python -m compile.aot --out ../artifacts

For each (model, dataset, size) in presets.DEFAULT_EXPORTS this writes

    artifacts/<model>_<dataset>_<size>/
        a_fwd.hlo.txt  a_upd.hlo.txt  a_local.hlo.txt  a_grad_cos.hlo.txt
        b_step.hlo.txt b_local.hlo.txt b_eval.hlo.txt
        manifest.json

HLO TEXT is the interchange format, not `.serialize()`: jax ≥ 0.5 emits
HloModuleProto with 64-bit instruction ids which the xla crate's
xla_extension 0.5.1 rejects (`proto.id() <= INT_MAX`); the text parser
reassigns ids and round-trips cleanly (see /opt/xla-example/README.md).

The manifest records every shape/dtype the Rust coordinator needs: the flat
parameter ABI (name, shape, init kind) per party, the data input shapes,
and the artifact file map. rust/src/runtime/artifacts.rs is the consumer.
"""

import argparse
import json
import os
import sys

import jax
import jax.numpy as jnp
from jax._src.lib import xla_client as xc

from . import presets
from .models import bottom_param_shapes, top_param_shapes
from .steps import StepBuilder, WSTATS_LEN


def to_hlo_text(lowered) -> str:
    """StableHLO → XlaComputation → HLO text (id-safe interchange)."""
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True)
    return comp.as_hlo_text()


def _init_kind(name: str) -> str:
    """Parameter init policy executed by rust/src/runtime/params.rs."""
    if name == "emb":
        return "normal_0.01"
    if name.startswith("w"):            # w1, w2, wt1, wt2, wide, wide_top
        return "glorot" if name not in ("wide", "wide_top") else "zeros"
    if name == "scale":
        return "ones"
    return "zeros"                       # biases


def _shape_entry(name, shape):
    return {"name": name, "shape": list(shape), "init": _init_kind(name)}


def export_one(model: str, dataset: str, size: str, out_root: str,
               verbose: bool = True, parties: int = 2) -> dict:
    ds = presets.DATASETS[dataset]
    if parties != 2:
        # K-party preset: the bottom model is compiled for one vertical
        # slice of the Party-A feature space (fields_a = split width),
        # shared by all K-1 feature parties. The label party's own
        # fields_b bottom is unchanged. Write these to a dedicated
        # --out root: the artifact tag is still <model>_<dataset>_<size>
        # and the rust loader picks the root via `artifacts_dir`.
        ds = presets.vertical_slice(ds, parties)
    spec = presets.SIZES[size]
    sb = StepBuilder(model, ds, spec)
    b, zd = spec.batch, spec.z_dim

    shapes_a = bottom_param_shapes(model, ds.fields_a, spec)
    shapes_b = (bottom_param_shapes(model, ds.fields_b, spec)
                + top_param_shapes(model, spec))
    pa = [jax.ShapeDtypeStruct(s, jnp.float32) for _, s in shapes_a]
    pb = [jax.ShapeDtypeStruct(s, jnp.float32) for _, s in shapes_b]
    aa = pa  # AdaGrad accumulators share param shapes
    ab = pb

    xa = jax.ShapeDtypeStruct((b, ds.fields_a), jnp.int32)
    xb = jax.ShapeDtypeStruct((b, ds.fields_b), jnp.int32)
    y = jax.ShapeDtypeStruct((b,), jnp.float32)
    za = jax.ShapeDtypeStruct((b, zd), jnp.float32)
    dza = jax.ShapeDtypeStruct((b, zd), jnp.float32)
    scalar = jax.ShapeDtypeStruct((), jnp.float32)

    entries = {
        "a_fwd": (sb.a_fwd, [*pa, xa]),
        "a_upd": (sb.a_upd, [*pa, *aa, xa, dza, scalar]),
        "a_local": (sb.a_local,
                    [*pa, *aa, xa, za, dza, scalar, scalar, scalar]),
        "a_grad_cos": (sb.a_grad_cos, [*pa, xa, dza, dza]),
        "b_step": (sb.b_step, [*pb, *ab, xb, y, za, scalar]),
        "b_local": (sb.b_local,
                    [*pb, *ab, xb, y, za, dza, scalar, scalar, scalar]),
        "b_eval": (sb.b_eval, [*pb, xb, za]),
    }

    tag = f"{model}_{dataset}_{size}"
    out_dir = os.path.join(out_root, tag)
    os.makedirs(out_dir, exist_ok=True)
    files = {}
    for name, (fn, args) in entries.items():
        # keep_unused: positional-ABI stability — XLA must not DCE
        # params whose *values* are unused (e.g. biases in grad-only
        # artifacts); the rust runtime feeds all of them positionally.
        lowered = jax.jit(fn, keep_unused=True).lower(*args)
        text = to_hlo_text(lowered)
        fname = f"{name}.hlo.txt"
        with open(os.path.join(out_dir, fname), "w") as f:
            f.write(text)
        files[name] = fname
        if verbose:
            print(f"  {tag}/{fname}: {len(text)} chars", file=sys.stderr)

    manifest = {
        "abi_version": 1,
        "model": model,
        "dataset": dataset,
        "size": size,
        # Session size the bottom-model slice was compiled for (2 = the
        # classic full-width Party-A bottom). Informational: the rust
        # loader keys on fields_a, and ignores unknown manifest fields.
        "parties": parties,
        "batch": b,
        "z_dim": zd,
        "fields_a": ds.fields_a,
        "fields_b": ds.fields_b,
        "vocab": spec.vocab,
        "emb_dim": spec.emb_dim,
        "hidden": spec.hidden,
        "top_hidden": spec.top_hidden,
        "wstats_len": WSTATS_LEN,
        "params_a": [_shape_entry(n, s) for n, s in shapes_a],
        "params_b": [_shape_entry(n, s) for n, s in shapes_b],
        "files": files,
    }
    with open(os.path.join(out_dir, "manifest.json"), "w") as f:
        json.dump(manifest, f, indent=1)
    return manifest


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out", default="../artifacts",
                    help="artifact output root")
    ap.add_argument("--only", default=None,
                    help="export a single 'model,dataset,size' triple")
    ap.add_argument("--parties", type=int, default=2,
                    help="compile bottom models for the K-party vertical "
                         "slice (fields_a = fields_a / (K-1); requires an "
                         "even split). Use a dedicated --out root — the "
                         "artifact tag does not encode K.")
    args = ap.parse_args()
    if args.only:
        triples = [tuple(args.only.split(","))]
    else:
        triples = presets.DEFAULT_EXPORTS
    if args.parties != 2:
        # Pre-validate the whole matrix before writing anything: a
        # mid-loop ValueError would leave a partially populated
        # artifact root with no record of what succeeded. Explicit
        # --only requests fail hard; default-matrix exports skip the
        # datasets that cannot split evenly and say so.
        kept, skipped = [], []
        for triple in triples:
            try:
                presets.vertical_slice(presets.DATASETS[triple[1]],
                                       args.parties)
                kept.append(triple)
            except ValueError as e:
                if args.only:
                    raise SystemExit(f"error: {e}")
                skipped.append((triple, str(e)))
        for (model, dataset, size), why in skipped:
            print(f"skipping {model}_{dataset}_{size}: {why}",
                  file=sys.stderr)
        triples = kept
    for model, dataset, size in triples:
        export_one(model, dataset, size, args.out, parties=args.parties)
    print(f"exported {len(triples)} artifact sets to {args.out}"
          + (f" (per-slice bottoms for --parties {args.parties})"
             if args.parties != 2 else ""),
          file=sys.stderr)


if __name__ == "__main__":
    main()
