"""Pure-jnp oracles for the Pallas kernels (the correctness ground truth).

Every kernel in this package has a reference implementation here written in
straight-line jax.numpy. pytest (python/tests/test_kernels.py) asserts
allclose between the Pallas interpret-mode kernels and these oracles under
hypothesis-driven shape/value sweeps.
"""

import jax.numpy as jnp

# Matches the epsilon used inside the Pallas kernels; guards 0/0 for
# all-zero rows (cosine of a zero vector is defined as 0 here, which maps
# to weight 0 — the conservative choice for a zero gradient row).
COS_EPS = 1e-12


def cosine_weights_ref(v_new, v_stale, cos_thresh):
    """Row-wise cosine similarity with thresholding (Algorithm 2, InsWeight).

    Returns (weights, cos): `cos[k] = cos(v_new[k], v_stale[k])`, and
    `weights[k] = cos[k] if cos[k] >= cos_thresh else 0`.
    """
    dot = jnp.sum(v_new * v_stale, axis=1)
    nn = jnp.sum(v_new * v_new, axis=1)
    ns = jnp.sum(v_stale * v_stale, axis=1)
    cos = dot / (jnp.sqrt(nn * ns) + COS_EPS)
    w = jnp.where(cos >= cos_thresh, cos, jnp.zeros_like(cos))
    return w, cos


def apply_weights_ref(v, w):
    """Row scaling: out[k, :] = w[k] * v[k, :]."""
    return v * w[:, None]


def weighted_grad_ref(acts, grads, w):
    """Weighted outer-product contraction for a dense layer's weight grad.

    dW = acts^T (w ⊙ grads)   with acts [B, Din], grads [B, Dout], w [B].
    """
    return acts.T @ (grads * w[:, None])
