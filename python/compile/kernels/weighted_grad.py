"""Pallas kernel: weighted dense-layer weight gradient  dW = A^T (w ⊙ G).

This is the second L1 hot spot: the weight gradient of the bottom model's
output layer under CELU-VFL's instance weighting. Fusing the w⊙ broadcast
into the contraction avoids materialising the weighted cotangent [B, Dout]
in HBM before the matmul.

TPU mapping: grid walks the batch dimension in blocks; each step feeds one
[blk, Din] activation tile and one [blk, Dout] cotangent tile to the MXU
(f32 here; bf16 inputs with f32 accumulation on real hardware) and
accumulates into a VMEM-resident [Din, Dout] f32 scratch that is written
out once. Because the output block index is constant across the grid, the
accumulator tile stays pinned in VMEM for the whole contraction — the
Pallas revisiting-output pattern, the analogue of a CUDA threadblock
accumulating in registers/shared memory across a K-loop.
"""

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from .cosine_weights import _pick_block


def _kernel(a_ref, g_ref, w_ref, o_ref):
    i = pl.program_id(0)
    a = a_ref[...]
    gw = g_ref[...] * w_ref[...][:, None]
    part = jnp.dot(a.T, gw, preferred_element_type=jnp.float32)

    @pl.when(i == 0)
    def _init():
        o_ref[...] = part

    @pl.when(i > 0)
    def _acc():
        o_ref[...] += part


@jax.jit
def weighted_grad(acts, grads, w):
    """dW = acts^T (w ⊙ grads).  acts: [B, Din], grads: [B, Dout], w: [B]."""
    b, din = acts.shape
    _, dout = grads.shape
    blk = _pick_block(b)
    return pl.pallas_call(
        _kernel,
        grid=(b // blk,),
        in_specs=[
            pl.BlockSpec((blk, din), lambda i: (i, 0)),
            pl.BlockSpec((blk, dout), lambda i: (i, 0)),
            pl.BlockSpec((blk,), lambda i: (i,)),
        ],
        out_specs=pl.BlockSpec((din, dout), lambda i: (0, 0)),
        out_shape=jax.ShapeDtypeStruct((din, dout), jnp.float32),
        interpret=True,
    )(acts.astype(jnp.float32), grads.astype(jnp.float32),
      w.astype(jnp.float32))
