"""Pallas kernel: fused per-instance row scaling  out = w[:, None] * v.

Used on the local-update backward path to apply the staleness weights to a
cotangent (Party A: `ins_weights ⊙ ∇Z_A^(i)`, Algorithm 2 line 8) and to
per-instance losses reshaped to [B, 1] (Party B, line 14). Trivially
bandwidth-bound; the fusion win is avoiding a broadcast temp in HBM.
"""

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from .cosine_weights import _pick_block


def _kernel(v_ref, w_ref, o_ref):
    o_ref[...] = v_ref[...] * w_ref[...][:, None]


@jax.jit
def apply_weights(v, w):
    """Row scaling: out[k, :] = w[k] * v[k, :].  v: [B, D] f32, w: [B] f32."""
    b, d = v.shape
    blk = _pick_block(b)
    return pl.pallas_call(
        _kernel,
        grid=(b // blk,),
        in_specs=[
            pl.BlockSpec((blk, d), lambda i: (i, 0)),
            pl.BlockSpec((blk,), lambda i: (i,)),
        ],
        out_specs=pl.BlockSpec((blk, d), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((b, d), jnp.float32),
        interpret=True,
    )(v.astype(jnp.float32), w.astype(jnp.float32))
