"""Pallas kernel: fused row-wise cosine similarity + threshold (InsWeight).

This is the per-instance staleness measurement of CELU-VFL (Algorithm 2).
It runs on *every* local update on both parties, over [B, z_dim] statistics
matrices, so it is one of the two L1 hot spots.

TPU mapping (see DESIGN.md §Hardware-Adaptation): the grid walks row blocks;
each step streams one [blk, D] tile of `v_new` and `v_stale` HBM→VMEM and
fuses three row reductions (dot, |new|², |stale|²), the rsqrt, the
threshold compare and the select in a single VMEM-resident pass — no
intermediate results ever touch HBM. VMEM footprint per step is
2·blk·D·4 bytes (+2 output stripes), far under the ~16 MiB/core budget for
every preset in presets.py.

CPU PJRT cannot execute Mosaic custom-calls, so the kernel is lowered with
interpret=True; correctness is pinned to kernels/ref.py by pytest.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from .ref import COS_EPS


def _pick_block(b: int) -> int:
    """Largest row block ≤128 that divides B (presets keep B a mult. of 64)."""
    for blk in (128, 64, 32, 16, 8, 4, 2, 1):
        if b % blk == 0:
            return blk
    return 1


def _kernel(v_new_ref, v_stale_ref, thr_ref, w_ref, cos_ref):
    vn = v_new_ref[...]
    vs = v_stale_ref[...]
    dot = jnp.sum(vn * vs, axis=1)
    nn = jnp.sum(vn * vn, axis=1)
    ns = jnp.sum(vs * vs, axis=1)
    cos = dot / (jnp.sqrt(nn * ns) + COS_EPS)
    thr = thr_ref[0]
    w_ref[...] = jnp.where(cos >= thr, cos, jnp.zeros_like(cos))
    cos_ref[...] = cos


@functools.partial(jax.jit, static_argnames=())
def cosine_weights(v_new, v_stale, cos_thresh):
    """Fused InsWeight. Returns (weights [B], raw cos [B]).

    v_new, v_stale: [B, D] f32. cos_thresh: scalar (or shape-(1,)) f32 —
    `cos ξ` in the paper; weights below it are zeroed. The raw cosine is
    also returned for the Figure 5(d) staleness telemetry.
    """
    b, d = v_new.shape
    blk = _pick_block(b)
    thr = jnp.reshape(cos_thresh, (1,)).astype(jnp.float32)
    grid = (b // blk,)
    return pl.pallas_call(
        _kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((blk, d), lambda i: (i, 0)),
            pl.BlockSpec((blk, d), lambda i: (i, 0)),
            pl.BlockSpec((1,), lambda i: (0,)),
        ],
        out_specs=[
            pl.BlockSpec((blk,), lambda i: (i,)),
            pl.BlockSpec((blk,), lambda i: (i,)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((b,), jnp.float32),
            jax.ShapeDtypeStruct((b,), jnp.float32),
        ],
        interpret=True,
    )(v_new.astype(jnp.float32), v_stale.astype(jnp.float32), thr)
