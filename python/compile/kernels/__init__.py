"""L1: Pallas kernels for CELU-VFL's per-instance hot spots.

- cosine_weights: fused InsWeight (row cosine + threshold), Algorithm 2.
- apply_weights:  fused per-instance cotangent/loss scaling.
- weighted_grad:  fused weighted dense weight-gradient A^T (w ⊙ G).
- ref:            pure-jnp oracles for all of the above.

All kernels lower with interpret=True (CPU PJRT cannot run Mosaic
custom-calls); see DESIGN.md §Hardware-Adaptation for the TPU mapping.
"""

from .cosine_weights import cosine_weights
from .apply_weights import apply_weights
from .weighted_grad import weighted_grad

__all__ = ["cosine_weights", "apply_weights", "weighted_grad"]
