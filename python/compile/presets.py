"""Model/dataset/size presets shared by the AOT exporter, tests and docs.

The rust coordinator is shape-agnostic: every shape it needs is read from
the artifact manifest emitted by aot.py. These presets are therefore the
single source of truth for the static shapes baked into the HLO artifacts.

Dataset field splits follow Table 1 of the paper:
  criteo: 26 fields at Party A / 13 at Party B
  avazu : 14 / 8
  d3    : 25 / 18   (Tencent production dataset; simulated here)
"""

from dataclasses import dataclass, asdict


@dataclass(frozen=True)
class DatasetSpec:
    name: str
    fields_a: int
    fields_b: int


@dataclass(frozen=True)
class SizeSpec:
    """Static dimensions baked into one artifact set.

    batch:    mini-batch size B (paper: 4096)
    vocab:    hash-bucket count per categorical field
    emb_dim:  embedding dim per field
    hidden:   bottom MLP hidden width
    z_dim:    output dimensionality of Z_P (paper: 256)
    top_hidden: top MLP hidden width (WDL only)
    """

    name: str
    batch: int
    vocab: int
    emb_dim: int
    hidden: int
    z_dim: int
    top_hidden: int


DATASETS = {
    "criteo": DatasetSpec("criteo", 26, 13),
    "avazu": DatasetSpec("avazu", 14, 8),
    "d3": DatasetSpec("d3", 25, 18),
}

SIZES = {
    # tiny: CI / unit-test scale; keeps interpret-mode pallas fast.
    "tiny": SizeSpec("tiny", batch=64, vocab=100, emb_dim=4, hidden=32,
                     z_dim=16, top_hidden=32),
    # small: default experiment scale for the 1-core CPU testbed.
    "small": SizeSpec("small", batch=256, vocab=1000, emb_dim=8, hidden=128,
                      z_dim=64, top_hidden=64),
    # paper: the paper's protocol (B=4096, d(Z_A)=256). Export on demand:
    # compute per step is heavy for a 1-core CPU CI but the artifacts are
    # valid — used for the ~100M-param end-to-end config.
    "paper": SizeSpec("paper", batch=4096, vocab=50000, emb_dim=16,
                      hidden=512, z_dim=256, top_hidden=256),
    # big: ~100M parameters total (embedding-dominated), moderate batch so
    # the end-to-end example can run a few hundred steps on CPU.
    "big": SizeSpec("big", batch=256, vocab=65536, emb_dim=32, hidden=256,
                    z_dim=64, top_hidden=64),
}

MODELS = ("wdl", "dssm")


def vertical_slice(ds: DatasetSpec, parties: int) -> DatasetSpec:
    """Per-feature-party dataset spec for a K-party session.

    The rust trainer splits the Party-A feature space into K-1
    contiguous column slices (``PartyAData::vertical_split``) and
    requires every slice to match the bottom-model artifact's input
    width, so K-party artifacts are only well-defined when the split is
    even. Returns ``ds`` with ``fields_a`` replaced by the slice width;
    ``fields_b`` (the label party's own features) is untouched.
    """
    if parties < 3:
        raise ValueError(
            f"--parties {parties}: per-slice artifacts only exist for "
            "K >= 3 (K = 2 is the classic two-party split, use the "
            "default export)")
    k = parties - 1
    if k > ds.fields_a:
        raise ValueError(
            f"{ds.name}: cannot split {ds.fields_a} Party-A fields "
            f"across {k} feature parties")
    if ds.fields_a % k:
        valid = [p + 1 for p in range(2, ds.fields_a + 1)
                 if ds.fields_a % p == 0]
        raise ValueError(
            f"{ds.name}: {ds.fields_a} Party-A fields do not split "
            f"evenly across {k} feature parties (every party's bottom "
            f"model must share one artifact set) — valid --parties for "
            f"{ds.name}: {valid}")
    return DatasetSpec(ds.name, ds.fields_a // k, ds.fields_b)

# The default artifact matrix built by `make artifacts`.
DEFAULT_EXPORTS = [
    ("wdl", "criteo", "tiny"),
    ("dssm", "criteo", "tiny"),
    ("wdl", "criteo", "small"),
    ("dssm", "criteo", "small"),
    ("wdl", "avazu", "small"),
    ("dssm", "avazu", "small"),
    ("wdl", "d3", "small"),
    ("dssm", "d3", "small"),
    ("wdl", "criteo", "big"),
]


def spec_dict(model: str, dataset: str, size: str) -> dict:
    ds, sz = DATASETS[dataset], SIZES[size]
    return {"model": model, "dataset": asdict(ds), "size": asdict(sz)}
