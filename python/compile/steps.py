"""L2: the per-party training-step functions — the artifact entry points.

Each function here is AOT-lowered by aot.py into one HLO artifact that the
Rust coordinator loads and executes on the PJRT CPU client. The calling
convention (the wire ABI, mirrored in rust/src/runtime/artifacts.rs):

  a_fwd    (θ_A…, xa)                                  → (za,)
  a_upd    (θ_A…, acc_A…, xa, dza, lr)                 → (θ_A'…, acc_A'…)
  a_local  (θ_A…, acc_A…, xa, za_stale, dza_stale,
            lr, cos_thr, use_weights)                  → (θ_A'…, acc_A'…, wstats)
  b_step   (θ_B…, acc_B…, xb, y, za, lr)               → (θ_B'…, acc_B'…, dza, loss)
  b_local  (θ_B…, acc_B…, xb, y, za_stale, dza_stale,
            lr, cos_thr, use_weights)                  → (θ_B'…, acc_B'…, loss, wstats)
  b_eval   (θ_B…, xb, za)                              → (yhat,)
  a_grad_cos (θ_A…, xa, dza1, dza2)                    → (probe,)   # [cosθ, ‖g1‖, ‖g2‖]

θ_P… / acc_P… are the flat positional parameter / AdaGrad-accumulator lists
(order defined in models.bottom_param_shapes / top_param_shapes). `wstats`
is the staleness telemetry vector for Figure 5(d), see WSTATS_QUANTILES.

Semantics follow Algorithm 2 of the paper exactly:
- Party A's local update recomputes the ad-hoc activations Z_A^(i,j),
  weights instances by cos(Z_A^(i,j), Z_A^(i)) thresholded at cos ξ, and
  backprops the weighted stale derivatives.
- Party B's local update feeds the stale Z_A^(i) to the top model, derives
  the ad-hoc ∇Z_A^(i,j), weights instances by cos(∇Z_A^(i,j), ∇Z_A^(i)),
  and backprops the weighted per-instance loss.
- The weighted "average" divides by B (not Σw): zero-weight instances
  contribute nothing, matching `ins_weights ⊙ loss` in Algorithm 2.
- `use_weights` (0.0 or 1.0) gates the whole mechanism at runtime: with 0
  the effective weights are pinned to 1, which is the paper's "No Weights"
  baseline and the FedBCD competitor — same artifact, no re-export.
"""

import jax
import jax.numpy as jnp

from .kernels import cosine_weights
from .models import (bce_rows, bottom_fwd, bottom_param_shapes, split_b_params,
                     top_fwd)
from .optimizer import adagrad_update

# wstats layout: [min, q10, q25, q50, q75, q90, mean, frac(w>0)]
WSTATS_QUANTILES = (0.0, 0.10, 0.25, 0.50, 0.75, 0.90)
WSTATS_LEN = 8


def _wstats(cos, w):
    qs = jnp.quantile(cos, jnp.asarray(WSTATS_QUANTILES, dtype=jnp.float32))
    return jnp.concatenate([
        qs,
        jnp.mean(cos)[None],
        jnp.mean((w > 0.0).astype(jnp.float32))[None],
    ])


def _ones(batch):
    return jnp.ones((batch,), dtype=jnp.float32)


def _gate_weights(w, use_weights):
    """w_eff = w if use_weights else 1 (branch-free select on a scalar)."""
    return use_weights * w + (1.0 - use_weights) * jnp.ones_like(w)


class StepBuilder:
    """Binds a (model, dataset, size) preset and emits the step functions."""

    def __init__(self, model, ds, spec):
        self.model = model
        self.ds = ds
        self.spec = spec
        self.n_bot_a = len(bottom_param_shapes(model, ds.fields_a, spec))

    # -- helpers -----------------------------------------------------------

    def _bot_a(self, params_a, xa, ins_w):
        return bottom_fwd(self.model, params_a, xa, ins_w,
                          self.ds.fields_a, self.spec)

    def _fwd_b(self, params_b, xb, za, ins_w):
        pb, pt = split_b_params(self.model, params_b, self.ds.fields_b,
                                self.spec)
        zb = bottom_fwd(self.model, pb, xb, ins_w, self.ds.fields_b,
                        self.spec)
        return top_fwd(self.model, pt, za, zb)

    # -- Party A -----------------------------------------------------------

    def a_fwd(self, *args):
        *params_a, xa = args
        return (self._bot_a(list(params_a), xa, _ones(self.spec.batch)),)

    def a_upd(self, *args):
        """Exact update: backprop the fresh ∇Z_A received from Party B."""
        n = self.n_bot_a
        params = list(args[:n])
        accs = list(args[n:2 * n])
        xa, dza, lr = args[2 * n:]
        ones = _ones(self.spec.batch)
        _, vjp = jax.vjp(lambda ps: self._bot_a(ps, xa, ones), params)
        grads = vjp(dza)[0]
        new_p, new_a = adagrad_update(params, accs, grads, lr)
        return tuple(new_p) + tuple(new_a)

    def a_local(self, *args):
        """Local update at Party A (Algorithm 2, LocalUpdatePartyA)."""
        n = self.n_bot_a
        params = list(args[:n])
        accs = list(args[n:2 * n])
        xa, za_stale, dza_stale, lr, cos_thr, use_weights = args[2 * n:]
        ones = _ones(self.spec.batch)
        za_new = self._bot_a(params, xa, ones)          # Z_A^(i,j)
        w, cos = cosine_weights(za_new, za_stale, cos_thr)
        w = _gate_weights(w, use_weights)
        # Weighted backward: the ins_w argument routes w through the
        # dense_weighted / scale_bwd custom VJPs (Pallas kernels).
        _, vjp = jax.vjp(lambda ps: self._bot_a(ps, xa, w), params)
        grads = vjp(dza_stale)[0]
        new_p, new_a = adagrad_update(params, accs, grads, lr)
        return tuple(new_p) + tuple(new_a) + (_wstats(cos, w),)

    def a_grad_cos(self, *args):
        """Probe: cosine between bottom-model grads under two cotangents.

        Directly estimates the paper's ρ (Assumption 1.2) — feed the fresh
        ∇Z_A^(i,j) and the stale ∇Z_A^(i) and read cos(g̃, g).
        """
        n = self.n_bot_a
        params = list(args[:n])
        xa, dza1, dza2 = args[n:]
        ones = _ones(self.spec.batch)
        _, vjp = jax.vjp(lambda ps: self._bot_a(ps, xa, ones), params)
        g1 = jnp.concatenate([g.ravel() for g in vjp(dza1)[0]])
        g2 = jnp.concatenate([g.ravel() for g in vjp(dza2)[0]])
        n1 = jnp.linalg.norm(g1)
        n2 = jnp.linalg.norm(g2)
        cos = jnp.dot(g1, g2) / (n1 * n2 + 1e-12)
        return (jnp.stack([cos, n1, n2]),)

    # -- Party B -----------------------------------------------------------

    def b_step(self, *args):
        """Exact step: full fwd/bwd with fresh Z_A; emits ∇Z_A and loss."""
        n = len(args) // 2 - 2  # params..accs..xb,y,za,lr
        params = list(args[:n])
        accs = list(args[n:2 * n])
        xb, y, za, lr = args[2 * n:]
        ones = _ones(self.spec.batch)

        def loss_fn(ps, za_in):
            logits = self._fwd_b(ps, xb, za_in, ones)
            return jnp.mean(bce_rows(y, logits))

        loss, (grads, dza) = jax.value_and_grad(loss_fn, argnums=(0, 1))(
            params, za)
        new_p, new_a = adagrad_update(params, accs, grads, lr)
        return tuple(new_p) + tuple(new_a) + (dza, loss[None])

    def b_local(self, *args):
        """Local update at Party B (Algorithm 2, LocalUpdatePartyB)."""
        n = (len(args) - 7) // 2
        params = list(args[:n])
        accs = list(args[n:2 * n])
        xb, y, za_stale, dza_stale, lr, cos_thr, use_weights = args[2 * n:]
        ones = _ones(self.spec.batch)

        def rows_fn(ps, za_in):
            logits = self._fwd_b(ps, xb, za_in, ones)
            return bce_rows(y, logits)

        # Ad-hoc derivatives ∇Z_A^(i,j) w.r.t. the (stale) activations.
        dza_new = jax.grad(
            lambda za_in: jnp.mean(rows_fn(params, za_in)))(za_stale)
        w, cos = cosine_weights(dza_new, dza_stale, cos_thr)
        w = jax.lax.stop_gradient(_gate_weights(w, use_weights))

        def wloss_fn(ps):
            return jnp.mean(w * rows_fn(ps, za_stale))

        loss, grads = jax.value_and_grad(wloss_fn)(params)
        new_p, new_a = adagrad_update(params, accs, grads, lr)
        return tuple(new_p) + tuple(new_a) + (loss[None], _wstats(cos, w))

    def b_eval(self, *args):
        """Validation forward: ŷ probabilities for AUC on the holdout."""
        *params, xb, za = args
        logits = self._fwd_b(list(params), xb, za, _ones(self.spec.batch))
        return (jax.nn.sigmoid(logits),)
